"""graph500 [graph]: the paper's own workload — 2D-partitioned BFS with
compressed collectives over Kronecker graphs (scale 22..30, edgefactor 16)."""

import dataclasses

from repro.configs import common


@dataclasses.dataclass(frozen=True)
class Graph500Config:
    name: str = "graph500"
    scale: int = 22
    edgefactor: int = 16
    mode: str = "auto"  # raw | bitmap | auto
    n_roots: int = 64  # benchmark spec: 64 BFS iterations


def model_config() -> Graph500Config:
    return Graph500Config()


def smoke_config() -> Graph500Config:
    return Graph500Config(scale=10, n_roots=4)


common.register(
    common.ArchSpec(
        arch_id="graph500",
        family="graph",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.GRAPH500_SHAPES,
        notes="the paper's workload; TEPS benchmark in benchmarks/teps.py",
    )
)
