"""gat-cora [gnn]: 2L d_hidden=8 n_heads=8 attention aggregator.
[arXiv:1710.10903; paper]"""

from repro.configs import common
from repro.models.gnn import GATConfig


def model_config(d_in: int = 1433, d_out: int = 7) -> GATConfig:
    return GATConfig(n_layers=2, d_hidden=8, n_heads=8, d_in=d_in, d_out=d_out)


def smoke_config() -> GATConfig:
    return GATConfig(n_layers=2, d_hidden=4, n_heads=2, d_in=16, d_out=4)


common.register(
    common.ArchSpec(
        arch_id="gat-cora",
        family="gnn",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.GNN_SHAPES,
        notes=(
            "cora-scale graphs fall below the compression/scale-out "
            "threshold; cells still run (replicated), per paper §5.4.3"
        ),
    )
)
