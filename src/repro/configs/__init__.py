"""Architecture configs: one module per assigned arch + the paper's own.

``get(arch_id)`` / ``list_archs()`` — see repro.configs.common.
"""

from repro.configs.common import ArchSpec, ShapeSpec, get, list_archs  # noqa: F401
