"""egnn [gnn]: 4L d_hidden=64 E(n)-equivariant. [arXiv:2102.09844; paper]"""

from repro.configs import common
from repro.models.gnn import EGNNConfig


def model_config(d_in: int = 16, d_out: int = 16) -> EGNNConfig:
    return EGNNConfig(n_layers=4, d_hidden=64, d_in=d_in, d_out=d_out)


def smoke_config() -> EGNNConfig:
    return EGNNConfig(n_layers=2, d_hidden=16, d_in=8, d_out=4)


common.register(
    common.ArchSpec(
        arch_id="egnn",
        family="gnn",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.GNN_SHAPES,
        notes="lossy payload quantization disabled on coordinate channels",
    )
)
