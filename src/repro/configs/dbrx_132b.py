"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""

from repro.configs import common
from repro.models.transformer import TransformerConfig


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name="dbrx-132b",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        top_k=4,
        d_ff_expert=10752,
        act="silu",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="dbrx-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=512,
        n_experts=4,
        top_k=2,
        d_ff_expert=96,
        moe_group=64,
        q_chunk=32,
        kv_chunk=32,
    )


common.register(
    common.ArchSpec(
        arch_id="dbrx-132b",
        family="lm",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.LM_SHAPES,
    )
)
