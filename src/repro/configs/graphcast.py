"""graphcast [gnn]: 16L d_hidden=512 mesh_refinement=6 sum-agg n_vars=227 —
encoder-processor-decoder mesh GNN. [arXiv:2212.12794; unverified]

Per DESIGN.md §5: the arch is the 16-layer interaction-network stack; the
*graph* for each of the 4 cells comes from the shape spec.  The refined
icosahedral multimesh itself is built by models/icosahedron.py and
exercised by examples/train_gnn.py."""

import functools

from repro.configs import common
from repro.models.gnn import GraphCastConfig


def model_config(d_in: int = 227, d_out: int = 227) -> GraphCastConfig:
    return GraphCastConfig(
        n_layers=16, d_hidden=512, d_in=d_in, d_out=d_out, mesh_refinement=6
    )


def smoke_config() -> GraphCastConfig:
    return GraphCastConfig(n_layers=2, d_hidden=32, d_in=16, d_out=8, mesh_refinement=2)


common.register(
    common.ArchSpec(
        arch_id="graphcast",
        family="gnn",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.GNN_SHAPES,
    )
)
