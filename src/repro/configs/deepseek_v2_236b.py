"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff_expert=1536
vocab=102400, MLA kv_lora=512, MoE 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]  (Simplification noted in DESIGN.md: HF's dense first
layer is made MoE like the rest so scan-over-layers stays uniform.)"""

from repro.configs import common
from repro.models.transformer import TransformerConfig


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # (unused in MoE layers; HF dense-layer width)
        vocab=102400,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        n_experts=8,
        top_k=2,
        n_shared_experts=2,
        d_ff_expert=32,
        moe_group=64,
        use_mla=True,
        kv_lora_rank=32,
        q_lora_rank=24,
        qk_rope_dim=8,
        qk_nope_dim=16,
        v_head_dim=16,
        q_chunk=32,
        kv_chunk=32,
    )


common.register(
    common.ArchSpec(
        arch_id="deepseek-v2-236b",
        family="lm",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.LM_SHAPES,
    )
)
