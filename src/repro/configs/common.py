"""Shared config machinery: shape sets per family, arch registry."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | graph_train | skip
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    skip_reason: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | graph
    model_config: Callable[[], Any]
    smoke_config: Callable[[], Any]
    shapes: tuple[ShapeSpec, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}")


# --- family shape sets (assigned-pool definitions, verbatim) ----------------

LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec(
        "long_500k",
        "skip",
        {"seq_len": 524288, "global_batch": 1},
        skip_reason=(
            "pure full-attention arch (MLA is still full attention over a "
            "latent KV); 512k decode requires sub-quadratic attention per "
            "the shape-set rule — recorded as SKIP (DESIGN.md §5)"
        ),
    ),
)

GNN_SHAPES = (
    ShapeSpec(
        "full_graph_sm",
        "graph_train",
        {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433, "n_classes": 7,
         "dist": "replicated"},
    ),
    ShapeSpec(
        "minibatch_lg",
        "graph_train",
        {"n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1_024,
         "fanout": (15, 10), "d_feat": 602, "n_classes": 41, "dist": "sampled"},
    ),
    ShapeSpec(
        "ogb_products",
        "graph_train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
         "n_classes": 47, "dist": "2d"},
    ),
    ShapeSpec(
        "molecule",
        "graph_train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
         "n_classes": 16, "dist": "batched"},
    ),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65_536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

GRAPH500_SHAPES = (
    ShapeSpec("scale22", "bfs", {"scale": 22, "edgefactor": 16}),
    ShapeSpec("scale27", "bfs", {"scale": 27, "edgefactor": 16}),
    ShapeSpec("scale30", "bfs", {"scale": 30, "edgefactor": 16}),
)

_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        autoint,
        dbrx_132b,
        deepseek_coder_33b,
        deepseek_v2_236b,
        egnn,
        gat_cora,
        gemma_2b,
        graph500,
        graphcast,
        minicpm_2b,
        nequip,
    )
