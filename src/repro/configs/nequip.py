"""nequip [gnn]: 5L d_hidden=32 l_max=2 n_rbf=8 cutoff=5, O(3)-equivariant
tensor products. [arXiv:2101.03164; paper]"""

from repro.configs import common
from repro.models.gnn import NequIPConfig


def model_config(d_in: int = 16, d_out: int = 16) -> NequIPConfig:
    return NequIPConfig(
        n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0, d_in=d_in, d_out=d_out
    )


def smoke_config() -> NequIPConfig:
    return NequIPConfig(n_layers=2, d_hidden=4, l_max=2, n_rbf=4, cutoff=5.0, d_in=8, d_out=4)


common.register(
    common.ArchSpec(
        arch_id="nequip",
        family="gnn",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.GNN_SHAPES,
        notes=(
            "equivariance-sensitive: wire payloads stay fp32 (lossless id "
            "compression only) — DESIGN.md §Arch-applicability"
        ),
    )
)
