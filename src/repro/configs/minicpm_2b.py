"""minicpm-2b [dense]: 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753,
WSD schedule, llama-like. [arXiv:2404.06395; hf]"""

from repro.configs import common
from repro.models.transformer import TransformerConfig


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-2b",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        head_dim=64,
        d_ff=5760,
        vocab=122753,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab=512,
        q_chunk=32,
        kv_chunk=32,
    )


common.register(
    common.ArchSpec(
        arch_id="minicpm-2b",
        family="lm",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.LM_SHAPES,
        notes="trains with the WSD schedule (optim/adamw.py)",
    )
)
