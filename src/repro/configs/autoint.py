"""autoint [recsys]: 39 sparse fields, embed_dim=16, 3 attn layers (2 heads,
d=32), self-attention feature interaction. [arXiv:1810.11921; paper]"""

from repro.configs import common
from repro.models.recsys import AutoIntConfig


def model_config() -> AutoIntConfig:
    return AutoIntConfig(
        n_sparse=39, embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32
    )


def smoke_config() -> AutoIntConfig:
    return AutoIntConfig(
        n_sparse=8,
        embed_dim=8,
        n_attn_layers=2,
        n_heads=2,
        d_attn=8,
        mlp_dims=(32,),
        table_sizes=tuple([256] * 8),
    )


common.register(
    common.ArchSpec(
        arch_id="autoint",
        family="recsys",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.RECSYS_SHAPES,
        notes=(
            "embedding rows exchanged all-to-all style by the row-sharded "
            "lookup — the paper's exact data shape (sorted hot ids); int8 "
            "payload + bitpacked id options"
        ),
    )
)
