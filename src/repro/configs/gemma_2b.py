"""gemma-2b [dense]: 18L d_model=2048 8H MQA (kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""

from repro.configs import common
from repro.models.transformer import TransformerConfig


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma-2b",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=256000,
        act="gelu",  # GeGLU
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab=512,
        act="gelu",
        q_chunk=32,
        kv_chunk=32,
    )


common.register(
    common.ArchSpec(
        arch_id="gemma-2b",
        family="lm",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.LM_SHAPES,
    )
)
