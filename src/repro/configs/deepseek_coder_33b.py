"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch. [arXiv:2401.14196; hf]"""

from repro.configs import common
from repro.models.transformer import TransformerConfig


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-33b",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab=32256,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab=512,
        q_chunk=32,
        kv_chunk=32,
    )


common.register(
    common.ArchSpec(
        arch_id="deepseek-coder-33b",
        family="lm",
        model_config=model_config,
        smoke_config=smoke_config,
        shapes=common.LM_SHAPES,
    )
)
