"""Pure-jnp oracle: SWAR popcount of a uint32 word stream."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_words(words: jax.Array) -> jax.Array:
    """Per-word bit counts (uint32 -> int32)."""
    v = words.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def popcount_total(words: jax.Array) -> jax.Array:
    return jnp.sum(popcount_words(words))
