"""Bitmap popcount kernel (paper §3.1 "Sparse vector with pop counting").

The CUDA ``__popc`` bitmap trick has no per-lane TPU analogue; the
TPU-idiomatic equivalent is a vectorized SWAR popcount over (8,128) uint32
tiles reduced in VMEM.  Used for frontier-size statistics that drive the
bucket selection and compression-threshold policy.
"""

from repro.kernels.popcount import ops, ref  # noqa: F401
