"""Dispatch layer for the popcount kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.popcount import popcount, ref


def popcount_blocks(words: jax.Array) -> jax.Array:
    if jax.default_backend() == "tpu" and words.shape[0] % popcount.WORDS_PER_BLOCK == 0:
        return popcount.popcount_blocks_pallas(words, interpret=False)
    blocks = words.reshape(-1, min(words.shape[0], popcount.WORDS_PER_BLOCK))
    return jnp.sum(ref.popcount_words(blocks), axis=1)


popcount_words = ref.popcount_words
popcount_total = ref.popcount_total
