"""Dispatch layer for the popcount kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.popcount import popcount, ref


def popcount_blocks(words: jax.Array) -> jax.Array:
    """Per-block popcounts of a uint32 word stream (any length: the last
    block is zero-padded to the kernel's 1024-word geometry)."""
    pad = (-words.shape[0]) % popcount.WORDS_PER_BLOCK
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), words.dtype)])
    if jax.default_backend() == "tpu":
        return popcount.popcount_blocks_pallas(words)
    blocks = words.reshape(-1, popcount.WORDS_PER_BLOCK)
    return jnp.sum(ref.popcount_words(blocks), axis=1)


def popcount_planes(words: jax.Array) -> jax.Array:
    """Per-plane popcounts of a ``(B, W)`` word matrix (any ``W``: each
    plane is zero-padded to the kernel's 1024-word block geometry).

    The multi-source frontier counter: one call reduces every source plane's
    packed bitmap, blocking the Pallas grid over ``B x words`` instead of
    looping the single-plane kernel per source.
    """
    b, w = words.shape
    pad = (-w) % popcount.WORDS_PER_BLOCK
    if pad:
        words = jnp.concatenate(
            [words, jnp.zeros((b, pad), words.dtype)], axis=1
        )
    if jax.default_backend() == "tpu":
        return jnp.sum(popcount.popcount_planes_pallas(words), axis=1)
    return jnp.sum(ref.popcount_words(words), axis=1)


popcount_words = ref.popcount_words
popcount_total = ref.popcount_total
