"""Pallas TPU kernel: blocked SWAR popcount with in-VMEM reduction.

Grid step = (8, 128) uint32 tile -> one int32 partial count. The SWAR adds
and the tree reduction happen in VMEM; HBM traffic is exactly one read of
the bitmap plus a (grid,) int32 write.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

TILE = (8, 128)
WORDS_PER_BLOCK = TILE[0] * TILE[1]


def _popcount_kernel(w_ref, o_ref):
    v = w_ref[...].astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    counts = ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    o_ref[...] = jnp.sum(counts).reshape(1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def popcount_blocks_pallas(words: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Per-1024-word-block popcounts; words length % 1024 == 0."""
    interpret = resolve_interpret(interpret)
    n = words.shape[0]
    assert n % WORDS_PER_BLOCK == 0, n
    grid = n // WORDS_PER_BLOCK
    w2 = words.astype(jnp.uint32).reshape(n // TILE[1], TILE[1])
    return pl.pallas_call(
        _popcount_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(TILE, lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.int32),
        interpret=interpret,
    )(w2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def popcount_planes_pallas(
    words: jax.Array, interpret: bool | None = None
) -> jax.Array:
    """Per-(plane, 1024-word-block) popcounts of a ``(B, W)`` word matrix.

    The multi-source batch axis as a leading grid dimension: the grid blocks
    over ``B x words`` so every plane's bitmap is reduced by the same SWAR
    kernel without a host-side loop over sources.  ``W % 1024 == 0``;
    returns ``(B, W // 1024)`` int32 partial counts (sum axis 1 for the
    per-plane totals).
    """
    interpret = resolve_interpret(interpret)
    b, w = words.shape
    assert w % WORDS_PER_BLOCK == 0, (b, w)
    blocks = w // WORDS_PER_BLOCK
    w2 = words.astype(jnp.uint32).reshape(b * w // TILE[1], TILE[1])
    out = pl.pallas_call(
        _popcount_kernel,
        grid=(b, blocks),
        in_specs=[
            pl.BlockSpec(TILE, lambda i, j, _bl=blocks: (i * _bl + j, 0))
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j, _bl=blocks: (i * _bl + j,)),
        out_shape=jax.ShapeDtypeStruct((b * blocks,), jnp.int32),
        interpret=interpret,
    )(w2)
    return out.reshape(b, blocks)
