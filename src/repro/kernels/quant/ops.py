"""Dispatch layer for the int8 quant kernel."""

from __future__ import annotations

import jax

from repro.kernels.quant import quant, ref


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    if jax.default_backend() == "tpu" and x.shape[0] % (quant.ROWS * ref.GROUP) == 0:
        return quant.quantize_pallas(x)
    return ref.quantize(x)


dequantize = ref.dequantize
