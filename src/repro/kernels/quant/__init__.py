"""Int8 block quantization kernel (gradient / payload compression).

Beyond-paper extension of the compression idea to *lossy* float payloads:
per-128-value max-abs scales, symmetric int8.  Used by
``optim/grad_compress.py`` (error-feedback DP gradient sync) and by the
optional quantized MoE dispatch / embedding exchange.
"""

from repro.kernels.quant import ops, ref  # noqa: F401
