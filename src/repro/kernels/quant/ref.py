"""Pure-jnp oracle for int8 block quantization."""

from __future__ import annotations

import jax
import jax.numpy as jnp

GROUP = 128  # values per scale group (one lane row)


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (N,) float -> (q int8 (N,), scales f32 (N/GROUP,)). N % GROUP == 0."""
    n = x.shape[0]
    assert n % GROUP == 0, n
    g = x.astype(jnp.float32).reshape(-1, GROUP)
    scale = jnp.max(jnp.abs(g), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(g / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    g = q.astype(jnp.float32).reshape(-1, GROUP) * scale[:, None]
    return g.reshape(-1).astype(dtype)
