"""Pallas TPU kernel: fused block-quantize (max-abs scale + round to int8).

Grid step = (8, 128) float32 tile -> (8, 128) int8 tile + (8,) row scales.
The reduction (max-abs) and the elementwise scale/round stay in VMEM; on TPU
this is one VPU pass instead of XLA's reduce + broadcast + round chain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from repro.kernels.quant.ref import GROUP

ROWS = 8  # rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (8, 128)
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0  # (8,)
    safe = jnp.where(scale > 0, scale, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / safe[:, None]), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pallas(x: jax.Array, interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    interpret = resolve_interpret(interpret)
    n = x.shape[0]
    assert n % (ROWS * GROUP) == 0, n
    grid = n // (ROWS * GROUP)
    x2 = x.astype(jnp.float32).reshape(n // GROUP, GROUP)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((ROWS, GROUP), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((ROWS, GROUP), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n // GROUP, GROUP), jnp.int8),
            jax.ShapeDtypeStruct((n // GROUP,), jnp.float32),
        ),
        interpret=interpret,
    )(x2)
    return q.reshape(-1), s
