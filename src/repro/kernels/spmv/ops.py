"""Dispatch layer for the ELL SpMV kernels (push, pull, plane-batched).

The Pallas kernels tile at ``ROW_TILE`` rows x ``DEG_CHUNK`` neighbor slots.
Off-multiple blocks used to fall silently to the interpret-speed reference
even on TPU; the dispatchers now *pad* instead — rows are extended with
all-sentinel (``n_cols``) neighbor lists that produce INF and are sliced
off, the degree axis with sentinel slots that never hit the frontier — so
the compiled path is reachable from any block geometry the expansion
backends produce.

``interpret=None`` keeps the backend rule (compiled kernel on TPU, jnp
reference elsewhere); passing an explicit bool forces the Pallas path in
that mode, which is how the padding wrappers are exercised on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spmv import pull, ref, spmv


def _use_kernel(interpret: bool | None) -> bool:
    return interpret is not None or jax.default_backend() == "tpu"


def _pad_nbr(nbr: jax.Array, n_cols: int) -> tuple[jax.Array, int]:
    """Pad an ELL block to the kernel tile: rows to ROW_TILE with sentinel
    ``n_cols`` neighbor lists, the degree axis to DEG_CHUNK with sentinel
    slots.  Returns (padded block, true row count to slice back to)."""
    n_rows, max_deg = nbr.shape
    rpad = -n_rows % spmv.ROW_TILE
    dpad = -max_deg % spmv.DEG_CHUNK
    if rpad or dpad:
        nbr = jnp.pad(nbr, ((0, rpad), (0, dpad)), constant_values=n_cols)
    return nbr, n_rows


def _pad_u_words(u_words: jax.Array, rows_pad: int) -> jax.Array:
    """Extend an unreached bitmap to cover padded rows (zero bits -> INF
    rows, which the row slice drops).  Works on (W,) and (B, W) layouts."""
    need = rows_pad // 32
    have = u_words.shape[-1]
    if have == need:
        return u_words
    assert have < need, (have, need)
    pad = [(0, 0)] * (u_words.ndim - 1) + [(0, need - have)]
    return jnp.pad(u_words, pad)


def spmv_min(
    nbr: jax.Array, f_words: jax.Array, n_cols: int, interpret: bool | None = None
) -> jax.Array:
    if not _use_kernel(interpret):
        return ref.spmv_min(nbr, f_words, n_cols)
    padded, n_rows = _pad_nbr(nbr, n_cols)
    return spmv.spmv_min_pallas(padded, f_words, n_cols, interpret=interpret)[:n_rows]


def spmv_pull_min(
    nbr: jax.Array,
    f_words: jax.Array,
    u_words: jax.Array,
    n_cols: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Pull direction: rows whose *unreached* bit is clear are masked to INF."""
    if not _use_kernel(interpret):
        return ref.spmv_pull_min(nbr, f_words, u_words, n_cols)
    padded, n_rows = _pad_nbr(nbr, n_cols)
    u_words = _pad_u_words(u_words, padded.shape[0])
    return pull.spmv_pull_min_pallas(
        padded, f_words, u_words, n_cols, interpret=interpret
    )[:n_rows]


def spmv_min_planes(
    nbr: jax.Array, f_words: jax.Array, n_cols: int, interpret: bool | None = None
) -> jax.Array:
    """Multi-source push: (B, n_cols/32) frontier planes -> (B, n_rows)."""
    if not _use_kernel(interpret):
        return ref.spmv_min_planes(nbr, f_words, n_cols)
    padded, n_rows = _pad_nbr(nbr, n_cols)
    return spmv.spmv_min_planes_pallas(padded, f_words, n_cols, interpret=interpret)[
        :, :n_rows
    ]


def spmv_pull_min_planes(
    nbr: jax.Array,
    f_words: jax.Array,
    u_words: jax.Array,
    n_cols: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-source pull: per-plane frontier AND unreached bitmaps."""
    if not _use_kernel(interpret):
        return ref.spmv_pull_min_planes(nbr, f_words, u_words, n_cols)
    padded, n_rows = _pad_nbr(nbr, n_cols)
    u_words = _pad_u_words(u_words, padded.shape[0])
    return pull.spmv_pull_min_planes_pallas(
        padded, f_words, u_words, n_cols, interpret=interpret
    )[:, :n_rows]


def gspmm_planes(
    nbr: jax.Array,
    f_words: jax.Array,
    x: jax.Array,
    n_cols: int,
    alg,
    *,
    row_base=0,
    col_base=0,
    u_words: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """op x reduce ELL value expansion over (B,) frontier/value planes.

    Each frontier hit proposes ``alg.edge_message(x[src], src + col_base,
    dst + row_base)``; candidates combine per destination row under the
    algebra's reduce.  Min-reduces compile to the Pallas value-gather
    kernel on TPU (op = ``"minplus"`` when the algebra consults edge
    weights, else ``"copy"``); sum-reduces and the CPU path instantiate
    the single :func:`repro.kernels.spmv.ref.gspmm` reference with the
    algebra's message closure.  ``u_words``, if given, masks finished
    destination rows to the algebra's empty sentinel (pull direction).
    """
    n_x = x.shape[1]
    if alg.reduce == "min" and _use_kernel(interpret):
        padded, n_rows = _pad_nbr(nbr, n_cols)
        if n_cols > n_x:
            x = jnp.pad(x, ((0, 0), (0, n_cols - n_x)), constant_values=alg.empty)
        bases = jnp.stack(
            [jnp.asarray(row_base, jnp.int32), jnp.asarray(col_base, jnp.int32)]
        ).reshape(1, 2)
        out = spmv.gspmm_min_planes_pallas(
            padded, f_words, x, bases, n_cols,
            op="minplus" if alg.uses_weights else "copy",
            max_weight=getattr(alg, "max_weight", 31),
            interpret=interpret,
        )[:, :n_rows]
        if u_words is not None:
            rows = jnp.arange(n_rows, dtype=jnp.int32)
            unreached = jax.vmap(lambda uw: ref.frontier_bit(uw, rows, n_rows))(
                u_words
            )
            out = jnp.where(unreached, out, alg.empty)
        return out

    if alg.reduce == "min":
        reduce = None
    else:
        reduce = lambda vals, axis: alg.enc(jnp.sum(alg.dec(vals), axis=axis))  # noqa: E731

    def one(fw, xp, uw):
        def message(rows, cols):
            xs = xp[jnp.minimum(cols, n_x - 1)]
            return alg.edge_message(xs, cols + col_base, rows + row_base)

        return ref.gspmm(
            nbr, fw, n_cols, message=message, reduce=reduce,
            empty=alg.empty, u_words=uw,
        )

    if u_words is None:
        return jax.vmap(lambda fw, xp: one(fw, xp, None))(f_words, x)
    return jax.vmap(one)(f_words, x, u_words)
