"""Dispatch layer for the ELL SpMV kernel."""

from __future__ import annotations

import jax

from repro.kernels.spmv import pull, ref, spmv


def spmv_min(nbr: jax.Array, f_words: jax.Array, n_cols: int) -> jax.Array:
    n_rows, max_deg = nbr.shape
    if (
        jax.default_backend() == "tpu"
        and n_rows % spmv.ROW_TILE == 0
        and max_deg % spmv.DEG_CHUNK == 0
    ):
        return spmv.spmv_min_pallas(nbr, f_words, n_cols)
    return ref.spmv_min(nbr, f_words, n_cols)


def spmv_pull_min(
    nbr: jax.Array, f_words: jax.Array, u_words: jax.Array, n_cols: int
) -> jax.Array:
    """Pull direction: rows whose *unreached* bit is clear are masked to INF."""
    n_rows, max_deg = nbr.shape
    if (
        jax.default_backend() == "tpu"
        and n_rows % pull.ROW_TILE == 0
        and max_deg % pull.DEG_CHUNK == 0
    ):
        return pull.spmv_pull_min_pallas(nbr, f_words, u_words, n_cols)
    return ref.spmv_pull_min(nbr, f_words, u_words, n_cols)


def spmv_min_planes(nbr: jax.Array, f_words: jax.Array, n_cols: int) -> jax.Array:
    """Multi-source push: (B, n_cols/32) frontier planes -> (B, n_rows)."""
    n_rows, max_deg = nbr.shape
    if (
        jax.default_backend() == "tpu"
        and n_rows % spmv.ROW_TILE == 0
        and max_deg % spmv.DEG_CHUNK == 0
    ):
        return spmv.spmv_min_planes_pallas(nbr, f_words, n_cols)
    return ref.spmv_min_planes(nbr, f_words, n_cols)


def spmv_pull_min_planes(
    nbr: jax.Array, f_words: jax.Array, u_words: jax.Array, n_cols: int
) -> jax.Array:
    """Multi-source pull: per-plane frontier AND unreached bitmaps."""
    n_rows, max_deg = nbr.shape
    if (
        jax.default_backend() == "tpu"
        and n_rows % pull.ROW_TILE == 0
        and max_deg % pull.DEG_CHUNK == 0
    ):
        return pull.spmv_pull_min_planes_pallas(nbr, f_words, u_words, n_cols)
    return ref.spmv_pull_min_planes(nbr, f_words, u_words, n_cols)
