"""Dispatch layer for the ELL SpMV kernel."""

from __future__ import annotations

import jax

from repro.kernels.spmv import ref, spmv


def spmv_min(nbr: jax.Array, f_words: jax.Array, n_cols: int) -> jax.Array:
    n_rows, max_deg = nbr.shape
    if (
        jax.default_backend() == "tpu"
        and n_rows % spmv.ROW_TILE == 0
        and max_deg % spmv.DEG_CHUNK == 0
    ):
        return spmv.spmv_min_pallas(nbr, f_words, n_cols, interpret=False)
    return ref.spmv_min(nbr, f_words, n_cols)
