"""Pallas TPU kernel: pull-direction (bottom-up) ELL frontier expansion.

The direction-optimized counterpart of :mod:`repro.kernels.spmv.spmv`: at
dense levels every *unreached* destination probes its neighbor tile against
the VMEM-resident frontier bitmap (Beamer's bottom-up step, paper §3.1).
The membership probe is the same vertical width-1 bitmap gather the push
kernel uses; the pull direction adds a second resident bitmap — the
unreached vector over the destination rows — that masks finished rows out
of the per-row min before it is accumulated.

Grid = (row tiles, degree chunks); both bitmaps use BlockSpecs with a
constant index map so they stay VMEM-resident across the whole grid (at
scale 30 the per-rank row bitmap is n_r/8 bytes — a few MB, well inside
v5e's 16 MB VMEM next to the column bitmap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from repro.kernels.spmv.ref import INF
from repro.kernels.spmv.spmv import DEG_CHUNK, ROW_TILE


def _pull_kernel(nbr_ref, f_ref, u_ref, o_ref, *, n_cols: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nbr = nbr_ref[...]  # (ROW_TILE, DEG_CHUNK) int32
    # frontier probe: identical bitmap gather to the push kernel
    safe = jnp.minimum(nbr, n_cols - 1)
    within = safe % 1024
    word_idx = (safe // 1024) * 32 + within % 32
    shift = (within // 32).astype(jnp.uint32)
    words = f_ref[word_idx]  # gather (ROW_TILE, DEG_CHUNK) uint32
    hit = ((words >> shift) & jnp.uint32(1)) == 1
    cand = jnp.where(hit & (nbr < n_cols), nbr, INF)
    tile_min = jnp.min(cand, axis=1)  # (ROW_TILE,)
    # unreached mask: probe the row bitmap at this tile's destination ids
    rows = i * ROW_TILE + jax.lax.broadcasted_iota(jnp.int32, (ROW_TILE, 1), 0)
    r_within = rows % 1024
    r_word = (rows // 1024) * 32 + r_within % 32
    r_shift = (r_within // 32).astype(jnp.uint32)
    unreached = ((u_ref[r_word] >> r_shift) & jnp.uint32(1)) == 1  # (ROW_TILE, 1)
    tile_min = jnp.where(unreached[:, 0], tile_min, INF)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = tile_min

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], tile_min)


def _pull_planes_kernel(nbr_ref, f_ref, u_ref, o_ref, *, n_cols: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nbr = nbr_ref[...]  # (ROW_TILE, DEG_CHUNK) int32
    safe = jnp.minimum(nbr, n_cols - 1)
    within = safe % 1024
    word_idx = (safe // 1024) * 32 + within % 32
    shift = (within // 32).astype(jnp.uint32)
    words = f_ref[0, word_idx]
    hit = ((words >> shift) & jnp.uint32(1)) == 1
    cand = jnp.where(hit & (nbr < n_cols), nbr, INF)
    tile_min = jnp.min(cand, axis=1)  # (ROW_TILE,)
    rows = i * ROW_TILE + jax.lax.broadcasted_iota(jnp.int32, (ROW_TILE, 1), 0)
    r_within = rows % 1024
    r_word = (rows // 1024) * 32 + r_within % 32
    r_shift = (r_within // 32).astype(jnp.uint32)
    unreached = ((u_ref[0, r_word] >> r_shift) & jnp.uint32(1)) == 1
    tile_min = jnp.where(unreached[:, 0], tile_min, INF).reshape(1, ROW_TILE)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = tile_min

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], tile_min)


@functools.partial(jax.jit, static_argnames=("n_cols", "interpret"))
def spmv_pull_min_planes_pallas(
    nbr: jax.Array,
    f_words: jax.Array,
    u_words: jax.Array,
    n_cols: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-source pull expansion with a leading plane axis on both
    bitmaps: ``f_words`` (B, n_cols/32) frontier planes, ``u_words``
    (B, n_rows/32) unreached planes -> (B, n_rows) per-plane mins."""
    interpret = resolve_interpret(interpret)
    b = f_words.shape[0]
    n_rows, max_deg = nbr.shape
    assert n_rows % ROW_TILE == 0, n_rows
    assert max_deg % DEG_CHUNK == 0, max_deg
    assert n_cols % 1024 == 0 and f_words.shape == (b, n_cols // 32)
    assert n_rows % 1024 == 0 and u_words.shape == (b, n_rows // 32)
    grid = (b, n_rows // ROW_TILE, max_deg // DEG_CHUNK)
    return pl.pallas_call(
        functools.partial(_pull_planes_kernel, n_cols=n_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, DEG_CHUNK), lambda p, i, j: (i, j)),
            pl.BlockSpec((1, n_cols // 32), lambda p, i, j: (p, 0)),  # resident
            pl.BlockSpec((1, n_rows // 32), lambda p, i, j: (p, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((1, ROW_TILE), lambda p, i, j: (p, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows), jnp.int32),
        interpret=interpret,
    )(nbr, f_words.astype(jnp.uint32), u_words.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("n_cols", "interpret"))
def spmv_pull_min_pallas(
    nbr: jax.Array,
    f_words: jax.Array,
    u_words: jax.Array,
    n_cols: int,
    interpret: bool | None = None,
) -> jax.Array:
    """nbr (n_rows, max_deg) int32 (pad = n_cols); f_words / u_words are
    vertical b=1 bitmaps over n_cols / n_rows bits -> (n_rows,) int32 min
    frontier neighbor for unreached rows, INF otherwise."""
    interpret = resolve_interpret(interpret)
    n_rows, max_deg = nbr.shape
    assert n_rows % ROW_TILE == 0, n_rows
    assert max_deg % DEG_CHUNK == 0, max_deg
    assert n_cols % 1024 == 0 and f_words.shape[0] == n_cols // 32
    assert n_rows % 1024 == 0 and u_words.shape[0] == n_rows // 32
    grid = (n_rows // ROW_TILE, max_deg // DEG_CHUNK)
    return pl.pallas_call(
        functools.partial(_pull_kernel, n_cols=n_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, DEG_CHUNK), lambda i, j: (i, j)),
            pl.BlockSpec((f_words.shape[0],), lambda i, j: (0,)),  # resident
            pl.BlockSpec((u_words.shape[0],), lambda i, j: (0,)),  # resident
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        interpret=interpret,
    )(nbr, f_words.astype(jnp.uint32), u_words.astype(jnp.uint32))
