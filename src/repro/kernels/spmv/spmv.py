"""Pallas TPU kernels: ELL frontier-expansion SpMV (op x reduce).

Grid = (row tiles, degree chunks).  Per step: a (1024, DC) neighbor tile
streams into VMEM, the frontier bitmap stays VMEM-resident (BlockSpec with
a constant index map — at scale 30 the per-rank column bitmap is
n_c/8 = 8 MB, inside v5e's 16 MB VMEM), membership bits are gathered and
the per-row reduce accumulates across degree chunks via output revisiting.

Two kernel families share that skeleton:

* ``spmv_min[_planes]_pallas`` — the min-parent BFS instantiation: the
  candidate IS the neighbor id (op = copy-id, reduce = min).
* ``gspmm_min_planes_pallas`` — the frontier-algebra value gather: each
  hit slot gathers the *source value* from a VMEM-resident per-plane value
  vector (op = ``"copy"``, CC label propagation) or adds the deterministic
  edge weight re-derived in-register from the global id pair (op =
  ``"minplus"``, SSSP; the same avalanche hash as
  :func:`repro.core.algebra.edge_weight`), reduce = min.  Sum-reduces
  (PageRank) stay on the XLA reference — float accumulation wants the
  decoded f32 domain, not the int32 transport.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from repro.kernels.spmv.ref import INF

ROW_TILE = 1024
DEG_CHUNK = 8


def _spmv_kernel(nbr_ref, f_ref, o_ref, *, n_cols: int):
    j = pl.program_id(1)
    nbr = nbr_ref[...]  # (ROW_TILE, DEG_CHUNK) int32
    safe = jnp.minimum(nbr, n_cols - 1)
    within = safe % 1024
    word_idx = (safe // 1024) * 32 + within % 32
    shift = (within // 32).astype(jnp.uint32)
    words = f_ref[word_idx]  # gather (ROW_TILE, DEG_CHUNK) uint32
    hit = ((words >> shift) & jnp.uint32(1)) == 1
    cand = jnp.where(hit & (nbr < n_cols), nbr, INF)
    tile_min = jnp.min(cand, axis=1)  # (ROW_TILE,)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = tile_min

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], tile_min)


def _spmv_planes_kernel(nbr_ref, f_ref, o_ref, *, n_cols: int):
    j = pl.program_id(2)
    nbr = nbr_ref[...]  # (ROW_TILE, DEG_CHUNK) int32
    safe = jnp.minimum(nbr, n_cols - 1)
    within = safe % 1024
    word_idx = (safe // 1024) * 32 + within % 32
    shift = (within // 32).astype(jnp.uint32)
    words = f_ref[0, word_idx]  # gather from this plane's resident bitmap
    hit = ((words >> shift) & jnp.uint32(1)) == 1
    cand = jnp.where(hit & (nbr < n_cols), nbr, INF)
    tile_min = jnp.min(cand, axis=1).reshape(1, ROW_TILE)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = tile_min

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], tile_min)


def _gspmm_planes_kernel(
    bases_ref, nbr_ref, f_ref, x_ref, o_ref, *, n_cols: int, op: str,
    max_weight: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nbr = nbr_ref[...]  # (ROW_TILE, DEG_CHUNK) int32
    safe = jnp.minimum(nbr, n_cols - 1)
    within = safe % 1024
    word_idx = (safe // 1024) * 32 + within % 32
    shift = (within // 32).astype(jnp.uint32)
    words = f_ref[0, word_idx]
    hit = ((words >> shift) & jnp.uint32(1)) == 1
    x = x_ref[0, safe]  # gather this plane's resident source values
    if op == "minplus":
        # re-derive the deterministic edge weight from the global id pair
        # (identical arithmetic to repro.core.algebra.edge_weight)
        rows = bases_ref[0, 0] + i * ROW_TILE + jax.lax.broadcasted_iota(
            jnp.int32, nbr.shape, 0
        )
        cols = bases_ref[0, 1] + nbr
        a = jnp.minimum(rows, cols).astype(jnp.uint32)
        b = jnp.maximum(rows, cols).astype(jnp.uint32)
        h = a * jnp.uint32(2654435761) ^ (
            b * jnp.uint32(40503) + jnp.uint32(2654435769)
        )
        h = h ^ (h >> jnp.uint32(16))
        w = (h % jnp.uint32(max_weight)).astype(jnp.int32) + 1
        cand = jnp.where(x >= INF - w, INF, x + w)
    else:
        assert op == "copy", op
        cand = x
    cand = jnp.where(hit & (nbr < n_cols), cand, INF)
    tile_min = jnp.min(cand, axis=1).reshape(1, ROW_TILE)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = tile_min

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], tile_min)


@functools.partial(
    jax.jit, static_argnames=("n_cols", "op", "max_weight", "interpret")
)
def gspmm_min_planes_pallas(
    nbr: jax.Array,
    f_words: jax.Array,
    x: jax.Array,
    bases: jax.Array,
    n_cols: int,
    op: str = "copy",
    max_weight: int = 31,
    interpret: bool | None = None,
) -> jax.Array:
    """Min-reduce value gather over frontier hits, plane-batched.

    ``x`` (B, n_cols) int32 per-plane source values (resident next to the
    plane's bitmap); ``bases`` (1, 2) int32 = (row_base, col_base) global
    id offsets of this rank's block — traced, so one compiled kernel
    serves every rank of the grid.  Returns (B, n_rows) reduced candidates
    (INF where no slot hit).
    """
    interpret = resolve_interpret(interpret)
    b = f_words.shape[0]
    n_rows, max_deg = nbr.shape
    assert n_rows % ROW_TILE == 0, n_rows
    assert max_deg % DEG_CHUNK == 0, max_deg
    assert n_cols % 1024 == 0 and f_words.shape[1] == n_cols // 32
    assert x.shape == (b, n_cols), (x.shape, b, n_cols)
    grid = (b, n_rows // ROW_TILE, max_deg // DEG_CHUNK)
    return pl.pallas_call(
        functools.partial(
            _gspmm_planes_kernel, n_cols=n_cols, op=op, max_weight=max_weight
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, i, j: (0, 0)),  # resident bases
            pl.BlockSpec((ROW_TILE, DEG_CHUNK), lambda p, i, j: (i, j)),
            pl.BlockSpec((1, n_cols // 32), lambda p, i, j: (p, 0)),  # resident
            pl.BlockSpec((1, n_cols), lambda p, i, j: (p, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((1, ROW_TILE), lambda p, i, j: (p, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows), jnp.int32),
        interpret=interpret,
    )(bases, nbr, f_words.astype(jnp.uint32), x)


@functools.partial(jax.jit, static_argnames=("n_cols", "interpret"))
def spmv_min_planes_pallas(
    nbr: jax.Array, f_words: jax.Array, n_cols: int, interpret: bool | None = None
) -> jax.Array:
    """Multi-source push expansion: the grid gains a leading plane axis.

    ``nbr`` (n_rows, max_deg) int32 (pad = n_cols); ``f_words`` (B, n_cols/32)
    packed frontier planes -> (B, n_rows) int32 per-plane min frontier
    neighbor / INF.  The neighbor tile streams once per (plane, row, degree)
    step while the *current plane's* bitmap stays VMEM-resident — the batch
    amortizes the frontier representation, not the edge traffic.
    """
    interpret = resolve_interpret(interpret)
    b = f_words.shape[0]
    n_rows, max_deg = nbr.shape
    assert n_rows % ROW_TILE == 0, n_rows
    assert max_deg % DEG_CHUNK == 0, max_deg
    assert n_cols % 1024 == 0 and f_words.shape[1] == n_cols // 32
    grid = (b, n_rows // ROW_TILE, max_deg // DEG_CHUNK)
    return pl.pallas_call(
        functools.partial(_spmv_planes_kernel, n_cols=n_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, DEG_CHUNK), lambda p, i, j: (i, j)),
            pl.BlockSpec((1, n_cols // 32), lambda p, i, j: (p, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((1, ROW_TILE), lambda p, i, j: (p, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows), jnp.int32),
        interpret=interpret,
    )(nbr, f_words.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("n_cols", "interpret"))
def spmv_min_pallas(
    nbr: jax.Array, f_words: jax.Array, n_cols: int, interpret: bool | None = None
) -> jax.Array:
    """nbr (n_rows, max_deg) int32 (pad = n_cols), f_words vertical b=1
    bitmap of n_cols bits -> (n_rows,) int32 min frontier neighbor / INF."""
    interpret = resolve_interpret(interpret)
    n_rows, max_deg = nbr.shape
    assert n_rows % ROW_TILE == 0, n_rows
    assert max_deg % DEG_CHUNK == 0, max_deg
    assert n_cols % 1024 == 0 and f_words.shape[0] == n_cols // 32
    grid = (n_rows // ROW_TILE, max_deg // DEG_CHUNK)
    return pl.pallas_call(
        functools.partial(_spmv_kernel, n_cols=n_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, DEG_CHUNK), lambda i, j: (i, j)),
            pl.BlockSpec((f_words.shape[0],), lambda i, j: (0,)),  # resident
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        interpret=interpret,
    )(nbr, f_words.astype(jnp.uint32))
