"""Pure-jnp oracle for the ELL frontier-expansion SpMV.

One parameterized reference — :func:`gspmm` (the DGL op x reduce shape) —
stands behind *every* entry point in this package: the min-parent BFS
semantics

    out[r] = min over d of ( nbr[r, d]  if frontier[nbr[r, d]] else INF )

is its ``message=None, reduce=None`` instantiation, the pull direction is
the same call with an unreached row mask, and the frontier-algebra value
expansions (SSSP min-plus, CC label copy, PageRank plus-times) pass their
own message/reduce closures.  The Pallas kernels are oracle-checked
against this one function.

``nbr``: (n_rows, max_deg) int32 destination-major neighbor lists, padded
with ``n_cols`` (which always misses the frontier).  ``frontier``: bitmap
of n_cols bits packed into uint32 words (vertical width-1 layout of
kernels/bitpack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


def ell_from_coo(src, dst, n_rows: int, n_cols: int, max_deg: int):
    """Host-free COO->ELL conversion (jnp; for tests and small blocks)."""
    order = jnp.argsort(dst)
    src_s, dst_s = src[order], dst[order]
    # position of each edge within its destination row
    ones = jnp.ones_like(dst_s)
    pos = jax.ops.segment_sum(ones, dst_s, num_segments=n_rows + 1)
    # recompute per-edge rank via cumsum trick
    idx = jnp.arange(dst_s.shape[0])
    row_start = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(pos)[:-1].astype(jnp.int32)])
    rank = idx - row_start[jnp.minimum(dst_s, n_rows)]
    nbr = jnp.full((n_rows + 1, max_deg), n_cols, jnp.int32)
    valid = (rank < max_deg) & (dst_s < n_rows)
    nbr = nbr.at[jnp.where(valid, dst_s, n_rows), jnp.where(valid, rank, 0)].set(
        jnp.where(valid, src_s, n_cols).astype(jnp.int32)
    )
    return nbr[:n_rows]


def frontier_bit(words: jax.Array, idx: jax.Array, n_cols: int) -> jax.Array:
    """Test membership bits for (possibly out-of-range) indices."""
    safe = jnp.minimum(idx, n_cols - 1)
    chunk, within = safe // 1024, safe % 1024
    w = words[chunk * 32 + within % 32]  # vertical b=1 layout: word j of chunk
    # vertical layout: value i at word (i % 32b=32) shift (i // 32): see bitpack
    shift = within // 32
    bit = (w >> shift) & jnp.uint32(1)
    return (bit == 1) & (idx < n_cols)


def gspmm(
    nbr: jax.Array,
    f_words: jax.Array,
    n_cols: int,
    *,
    message=None,
    reduce=None,
    empty=INF,
    u_words: jax.Array | None = None,
) -> jax.Array:
    """One op x reduce reference behind every ELL expansion entry point.

        out[r] = reduce over d of message(r, nbr[r, d])  where the slot's
                 source is in the frontier   (``empty`` if none hit)

    ``message(rows, cols)`` maps the (n_rows, max_deg) destination/source
    id grids to per-slot candidate values; ``None`` is the min-parent copy
    op (the candidate IS the source id).  ``reduce(vals, axis)`` defaults
    to ``jnp.min``; sum-algebras pass a decode-add-encode closure whose
    identity is their ``empty`` sentinel, so no extra masking is needed.
    ``u_words``, if given, is the packed unreached-row bitmap of the pull
    direction: finished destination rows collapse to ``empty``.
    """
    n_rows = nbr.shape[0]
    hit = frontier_bit(f_words, nbr, n_cols)
    if message is None:
        vals = nbr
    else:
        rows = jax.lax.broadcasted_iota(jnp.int32, nbr.shape, 0)
        vals = message(rows, nbr)
    cand = jnp.where(hit, vals, empty)
    out = (jnp.min if reduce is None else reduce)(cand, axis=1)
    if u_words is not None:
        unreached = frontier_bit(
            u_words, jnp.arange(n_rows, dtype=jnp.int32), n_rows
        )
        out = jnp.where(unreached, out, empty)
    return out


def spmv_min(nbr: jax.Array, f_words: jax.Array, n_cols: int) -> jax.Array:
    """out (n_rows,) int32 = min frontier neighbor id per row (INF if none)."""
    return gspmm(nbr, f_words, n_cols)


def spmv_min_planes(nbr: jax.Array, f_words: jax.Array, n_cols: int) -> jax.Array:
    """Multi-source push expansion: ``f_words`` is ``(B, n_cols/32)`` packed
    frontier planes -> ``(B, n_rows)`` per-plane min frontier neighbors."""
    return jax.vmap(lambda fw: spmv_min(nbr, fw, n_cols))(f_words)


def spmv_pull_min_planes(
    nbr: jax.Array, f_words: jax.Array, u_words: jax.Array, n_cols: int
) -> jax.Array:
    """Multi-source pull expansion: ``(B, n_cols/32)`` frontier planes and
    ``(B, n_rows/32)`` unreached planes -> ``(B, n_rows)`` per-plane mins."""
    return jax.vmap(lambda fw, uw: spmv_pull_min(nbr, fw, uw, n_cols))(
        f_words, u_words
    )


def spmv_pull_min(
    nbr: jax.Array, f_words: jax.Array, u_words: jax.Array, n_cols: int
) -> jax.Array:
    """Pull (bottom-up) expansion: only *unreached* rows probe their
    neighbor lists against the frontier bitmap.

    ``u_words``: vertical b=1 bitmap of n_rows bits — bit set when the row
    vertex is still unreached.  Rows with a clear bit produce INF (they
    neither need a parent nor should pay for the probe on hardware).
    """
    return gspmm(nbr, f_words, n_cols, u_words=u_words)
