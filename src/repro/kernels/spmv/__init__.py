"""Blocked ELL SpMV kernels: the BFS frontier-expansion hot spot.

The paper's §6 hand-optimizes exactly this loop with CPU SIMD (strength
reduction, vectorization of the matrix iteration).  The TPU analog: the
destination-major ELL neighbor tile streams through VMEM, the frontier
bitmap stays VMEM-resident, and the candidate-parent min-reduction runs on
the VPU — one (8,128) tile of destinations per grid step per degree chunk.

Two directions (Beamer, paper §3.1): ``spmv`` is the push (top-down)
kernel; ``pull`` is the bottom-up kernel, where only unreached rows probe
and a second resident bitmap masks finished destinations.
"""

from repro.kernels.spmv import ops, pull, ref  # noqa: F401
