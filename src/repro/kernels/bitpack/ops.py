"""jit'd dispatch layer for the bitpack kernel.

On TPU the Pallas kernel is used (compiled); elsewhere the pure-jnp oracle —
the two are bit-identical (tests sweep shapes x widths).  The public API is
what the compressed collectives call.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitpack import bitpack, ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def pack(values: jax.Array, b: int) -> jax.Array:
    if _use_pallas() and values.shape[0] % bitpack.VALS_PER_BLOCK == 0:
        return bitpack.pack_pallas(values, b)
    return ref.pack(values, b)


def unpack(words: jax.Array, b: int) -> jax.Array:
    if _use_pallas() and (words.shape[0] * 32 // b) % bitpack.VALS_PER_BLOCK == 0:
        return bitpack.unpack_pallas(words, b)
    return ref.unpack(words, b)


def pack_planes(values: jax.Array, b: int) -> jax.Array:
    """Pack a ``(B, n)`` plane matrix at width ``b`` -> ``(B, n*b/32)`` words.

    The vertical layout packs independent 1024-value chunks, so a
    chunk-aligned plane axis flattens losslessly: the Pallas kernel blocks
    over ``B x words`` in one grid instead of one launch per source plane.
    Requires ``n % 1024 == 0`` (every wire-format plane is chunk-aligned).
    """
    nplanes, n = values.shape
    assert n % ref.CHUNK == 0, (nplanes, n)
    return pack(values.reshape(-1), b).reshape(nplanes, -1)


def unpack_planes(words: jax.Array, b: int) -> jax.Array:
    """Inverse of :func:`pack_planes`: ``(B, W)`` words -> ``(B, W*32/b)``."""
    nplanes, w = words.shape
    assert (w * 32 // b) % ref.CHUNK == 0, (nplanes, w, b)
    return unpack(words.reshape(-1), b).reshape(nplanes, -1)


def pack_sorted_ids(ids: jax.Array, count: jax.Array, b: int) -> jax.Array:
    """Delta + pack a sorted id stream (paper's frontier codec)."""
    return pack(ref.gaps_from_sorted(ids, count), b)


def unpack_sorted_ids(words: jax.Array, count: jax.Array, b: int, fill: int) -> jax.Array:
    return ref.sorted_from_gaps(unpack(words, b), count, fill)


def compressed_words(capacity: int, b: int) -> int:
    """Static packed-word count for an id stream of ``capacity`` values."""
    assert capacity % ref.CHUNK == 0, capacity
    return capacity * b // 32


def compact_ids(mask_bits: jax.Array, capacity: int, fill: int) -> tuple[jax.Array, jax.Array]:
    """Stream-compact a boolean membership vector into sorted ids + count.

    jnp.nonzero with static ``size`` — jit-safe replacement for the GPU
    warp-scan compaction the paper's CUDA kernel uses.
    """
    (ids,) = jnp.nonzero(mask_bits, size=capacity, fill_value=fill)
    return ids.astype(jnp.int32), jnp.sum(mask_bits.astype(jnp.int32))
