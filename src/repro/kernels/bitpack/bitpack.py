"""Pallas TPU kernel for vertical bit packing (S4-BP128 analog).

Each grid step processes ``VALS_PER_BLOCK = 4096`` values — four 1024-value
chunks laid out as a (32, 128) int32 tile in VMEM — and emits a ``(b, 128)``
uint32 tile of packed words.  Every shift/OR acts on whole (8,128) vregs
along the sublane axis; there is no cross-lane traffic for b >= 4 and only
static in-tile reshapes for b in {1, 2} (see DESIGN.md §3).

Validated in interpret mode against :mod:`repro.kernels.bitpack.ref` over a
shape x bit-width sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret
from repro.kernels.bitpack.ref import B_CLASSES, CHUNK

VALS_PER_BLOCK = 4096  # 4 chunks = (32, 128) tile
_ROWS_IN = VALS_PER_BLOCK // 128  # 32


def _pack_kernel(v_ref, o_ref, *, b: int):
    k_per_word = 32 // b
    wc = 32 * b
    v = v_ref[...].astype(jnp.uint32)  # (32, 128)
    chunks = v.reshape(VALS_PER_BLOCK // CHUNK, k_per_word, wc)
    out = jnp.zeros((VALS_PER_BLOCK // CHUNK, wc), dtype=jnp.uint32)
    for k in range(k_per_word):
        out = out | (chunks[:, k, :] << jnp.uint32(k * b))
    o_ref[...] = out.reshape(b, 128)


def _unpack_kernel(w_ref, o_ref, *, b: int):
    k_per_word = 32 // b
    wc = 32 * b
    w = w_ref[...].astype(jnp.uint32).reshape(VALS_PER_BLOCK // CHUNK, 1, wc)
    shifts = (jnp.arange(k_per_word, dtype=jnp.uint32) * b)[None, :, None]
    mask = jnp.uint32((1 << b) - 1)
    vals = (w >> shifts) & mask  # (4, K, wc)
    o_ref[...] = vals.reshape(_ROWS_IN, 128)


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def pack_pallas(values: jax.Array, b: int, interpret: bool | None = None) -> jax.Array:
    """Pack uint32 values (length multiple of 4096) at width ``b``."""
    interpret = resolve_interpret(interpret)
    assert b in B_CLASSES, b
    if b == 32:
        return values.astype(jnp.uint32)
    n = values.shape[0]
    assert n % VALS_PER_BLOCK == 0, n
    grid = n // VALS_PER_BLOCK
    v2 = values.astype(jnp.uint32).reshape(n // 128, 128)
    out = pl.pallas_call(
        functools.partial(_pack_kernel, b=b),
        grid=(grid,),
        in_specs=[pl.BlockSpec((_ROWS_IN, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * b, 128), jnp.uint32),
        interpret=interpret,
    )(v2)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def unpack_pallas(words: jax.Array, b: int, interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`pack_pallas`."""
    interpret = resolve_interpret(interpret)
    assert b in B_CLASSES, b
    if b == 32:
        return words.astype(jnp.uint32)
    nw = words.shape[0]
    words_per_block = VALS_PER_BLOCK * b // 32  # = 128*b
    assert nw % words_per_block == 0, nw
    grid = nw // words_per_block
    w2 = words.astype(jnp.uint32).reshape(grid * b, 128)
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, b=b),
        grid=(grid,),
        in_specs=[pl.BlockSpec((b, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((_ROWS_IN, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * _ROWS_IN, 128), jnp.uint32),
        interpret=interpret,
    )(w2)
    return out.reshape(-1)
