"""S4-BP128-on-TPU: delta + binary packing of integer streams.

Vertical bit-packing over 1024-integer chunks — the (8,128)-vreg analog of
Lemire's 4-lane SSE "S4" layout (paper §5.2.B.vii).  ``ref`` is the pure-jnp
oracle (also the default in-graph implementation), ``bitpack`` the Pallas TPU
kernel, ``ops`` the jit'd dispatch layer.
"""

from repro.kernels.bitpack import ops, ref  # noqa: F401
from repro.kernels.bitpack.ref import B_CLASSES, CHUNK  # noqa: F401
