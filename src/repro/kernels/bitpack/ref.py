"""Pure-jnp oracle for the TPU bit-packing kernel (static shapes).

Layout ("vertical", per 1024-value chunk): with bit width ``b``, a chunk of
``CHUNK=1024`` uint32 values packs into ``Wc = 32*b`` words; word ``j`` of a
chunk holds values ``chunk[k*Wc + j]`` at bit offset ``k*b`` for
``k in range(32//b)``.  Consecutive *words* therefore take consecutive
values-strided-by-Wc — every shift/OR is a full-vector op with no cross-lane
traffic, exactly like Lemire's S4-BP128 SIMD layout (4 lanes there, 8x128
vregs here).

All functions are shape-static and jit/shard_map-safe: bit width ``b`` and
capacities are Python ints; runtime values never change shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 1024
B_CLASSES = (1, 2, 4, 8, 16, 32)  # lane-aligned subset of S4-BP128's 0..32


def words_for(n: int, b: int) -> int:
    """Packed word count for ``n`` values at width ``b`` (n % CHUNK == 0)."""
    assert n % CHUNK == 0, n
    return n * b // 32


def pack(values: jax.Array, b: int) -> jax.Array:
    """Pack uint32 ``values`` (< 2**b, length multiple of 1024) at width b."""
    assert b in B_CLASSES, b
    values = values.astype(jnp.uint32)
    if b == 32:
        return values
    k_per_word = 32 // b
    wc = CHUNK // k_per_word  # = 32*b
    v = values.reshape(-1, k_per_word, wc)
    out = jnp.zeros((v.shape[0], wc), dtype=jnp.uint32)
    for k in range(k_per_word):
        out = out | (v[:, k, :] << jnp.uint32(k * b))
    return out.reshape(-1)


def unpack(words: jax.Array, b: int) -> jax.Array:
    """Inverse of :func:`pack`; output length = words.size * 32 // b."""
    assert b in B_CLASSES, b
    words = words.astype(jnp.uint32)
    if b == 32:
        return words
    k_per_word = 32 // b
    wc = 32 * b
    w = words.reshape(-1, 1, wc)
    shifts = (jnp.arange(k_per_word, dtype=jnp.uint32) * b)[None, :, None]
    mask = jnp.uint32((1 << b) - 1)
    vals = (w >> shifts) & mask
    return vals.reshape(-1)


# ---------------------------------------------------------------------------
# delta (gap) coding of sorted id streams — fused with pack/unpack in-kernel
# ---------------------------------------------------------------------------


def gaps_from_sorted(ids: jax.Array, count: jax.Array) -> jax.Array:
    """Sorted ids (padded to static capacity) -> non-negative gaps.

    ``gaps[0] = ids[0]`` (absolute), ``gaps[i] = ids[i] - ids[i-1]``;
    positions >= count are zero.  ``count`` is a traced scalar.
    """
    cap = ids.shape[0]
    idx = jnp.arange(cap, dtype=jnp.int32)
    # Repeat the last valid id into the padding so padded gaps are zero.
    ids_m = ids[jnp.clip(jnp.minimum(idx, count - 1), 0, cap - 1)]
    prev = jnp.concatenate([jnp.zeros((1,), ids_m.dtype), ids_m[:-1]])
    gaps = jnp.where(idx < count, ids_m - prev, 0)
    return gaps.astype(jnp.uint32)


def sorted_from_gaps(gaps: jax.Array, count: jax.Array, fill: int) -> jax.Array:
    """Inverse of :func:`gaps_from_sorted`; padding positions get ``fill``."""
    ids = jnp.cumsum(gaps.astype(jnp.uint32), dtype=jnp.uint32).astype(jnp.int32)
    idx = jnp.arange(gaps.shape[0], dtype=jnp.int32)
    return jnp.where(idx < count, ids, jnp.int32(fill))


def required_width_class(gaps: jax.Array) -> jax.Array:
    """Smallest index into B_CLASSES whose width covers max(gaps) (traced)."""
    m = jnp.max(gaps).astype(jnp.uint32)
    cls = jnp.int32(len(B_CLASSES) - 1)
    for i in range(len(B_CLASSES) - 2, -1, -1):
        fits = m < jnp.uint32(1 << B_CLASSES[i])
        cls = jnp.where(fits, jnp.int32(i), cls)
    return cls


def pack_sorted_ids(ids: jax.Array, count: jax.Array, b: int) -> jax.Array:
    """Fused delta + pack of a sorted id stream (the paper's codec)."""
    return pack(gaps_from_sorted(ids, count), b)


def unpack_sorted_ids(words: jax.Array, count: jax.Array, b: int, fill: int) -> jax.Array:
    """Fused unpack + prefix-sum back to sorted ids."""
    return sorted_from_gaps(unpack(words, b), count, fill)
