"""Pallas TPU kernels for the compute hot spots the paper optimizes.

The paper's perf-critical layers: (a) the SIMD integer codec (its core
contribution) -> ``bitpack``; (b) bitmap popcounts (§3.1) -> ``popcount``;
(c) the SIMD-optimized SpMV inner loop (§6) -> ``spmv`` (ELL frontier
expansion with VMEM-resident bitmap).  Beyond-paper: ``quant`` (int8 block
quantization for gradient/payload compression).  Each kernel ships a
``pl.pallas_call`` + BlockSpec implementation, an ``ops.py`` jit'd wrapper
and a ``ref.py`` pure-jnp oracle; tests sweep shapes/dtypes/densities
against the oracles in interpret mode.
"""
