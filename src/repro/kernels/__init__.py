"""Pallas TPU kernels for the compute hot spots the paper optimizes.

The paper's perf-critical layers: (a) the SIMD integer codec (its core
contribution) -> ``bitpack``; (b) bitmap popcounts (§3.1) -> ``popcount``;
(c) the SIMD-optimized SpMV inner loop (§6) -> ``spmv`` (ELL frontier
expansion with VMEM-resident bitmap, push and pull directions).
Beyond-paper: ``quant`` (int8 block quantization for gradient/payload
compression).  Each kernel ships a ``pl.pallas_call`` + BlockSpec
implementation, an ``ops.py`` jit'd wrapper and a ``ref.py`` pure-jnp
oracle; tests sweep shapes/dtypes/densities against the oracles in
interpret mode.
"""

from __future__ import annotations

import jax


def interpret_default() -> bool:
    """Shared ``interpret=`` default for every Pallas entry point.

    Compiled on TPU, interpreted everywhere else — kernels resolve the
    backend once, here, instead of each entry point hard-coding a mode.
    Entry points take ``interpret: bool | None = None`` and resolve ``None``
    through this helper; an explicit bool still overrides (tests force
    interpret mode regardless of backend).
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an entry point's ``interpret`` argument (None -> backend default)."""
    return interpret_default() if interpret is None else interpret
