"""Compressed collectives for shard_map programs (paper Alg. 4 on TPU).

XLA collectives are static-shape — there is no ``MPI_Allgatherv``.  The
paper's variable-length compressed exchange is mapped to TPU as:

* **pack**: delta (gap) coding + vertical 16-bit binary packing with
  *patched exceptions* (Zukowski's PFOR, static exception capacity) — the
  paper's S4-BP128+delta, in the lane-aligned layout of
  :mod:`repro.kernels.bitpack`.
* **bucketing**: a small ladder of precompiled capacities; every rank
  computes the bucket it needs, a ``pmax`` over the collective's axis makes
  the choice uniform inside each communicator group, and ``lax.switch``
  dispatches to the branch whose collective carries exactly that many words.
  The dense-bitmap representation (= width-1 packing) is the always-valid
  fallback — this is simultaneously the paper's "adaptive data
  representation" row (§3.1) and its threshold mechanism (§5.4.3).

The collective operand genuinely shrinks in HLO, which is how the dry-run
roofline sees the savings.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitpack import ops as bp
from repro.kernels.bitpack import ref as bpref
from repro.kernels.quant import ref as quant

INF = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# static-shape patched id-stream codec (PFOR-16 with exception slots)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IdStreamSpec:
    """Static geometry of one packed sorted-id stream.

    cap: id capacity (multiple of 1024, <= 65536 so positions fit 16 bits).
    width: low-bits width (16 covers the paper's measured 15-bit entropy).
    """

    cap: int
    width: int = 16

    def __post_init__(self):
        assert self.cap % bpref.CHUNK == 0 and self.cap <= 1 << 16, self.cap
        assert self.width in (8, 16), self.width

    @property
    def exc_cap(self) -> int:
        return self.cap // 8

    @property
    def n_words(self) -> int:
        return self.cap * self.width // 32 + self.exc_cap


def pack_id_stream(ids: jax.Array, count: jax.Array, spec: IdStreamSpec):
    """Sorted ids (padded, int32) + count -> (words (n_words,), meta (2,)).

    meta = (count, exception_count).  Values must satisfy count <= spec.cap
    and exception_count <= spec.exc_cap — guaranteed by bucket selection.
    """
    ids = ids[: spec.cap]
    gaps = bpref.gaps_from_sorted(ids, count)  # uint32, zeros beyond count
    mask = jnp.uint32((1 << spec.width) - 1)
    low = gaps & mask
    high = gaps >> spec.width
    exc_pos, exc_count = bp.compact_ids(high > 0, spec.exc_cap, fill=spec.cap)
    exc_val = jnp.where(
        jnp.arange(spec.exc_cap) < exc_count,
        high[jnp.clip(exc_pos, 0, spec.cap - 1)],
        0,
    ).astype(jnp.uint32)
    exc_words = exc_pos.astype(jnp.uint32) | (exc_val << 16)
    low_words = bp.pack(low, spec.width)
    words = jnp.concatenate([low_words, exc_words])
    meta = jnp.stack([count.astype(jnp.int32), exc_count.astype(jnp.int32)])
    return words, meta


def unpack_id_stream(words: jax.Array, meta: jax.Array, spec: IdStreamSpec, fill: int):
    """Inverse of :func:`pack_id_stream` -> (ids (cap,) int32, count)."""
    count, exc_count = meta[0], meta[1]
    n_low = spec.cap * spec.width // 32
    low = bp.unpack(words[:n_low], spec.width)
    exc_words = words[n_low:]
    exc_pos = (exc_words & jnp.uint32(0xFFFF)).astype(jnp.int32)
    exc_val = exc_words >> 16
    valid = jnp.arange(spec.exc_cap) < exc_count
    pos = jnp.where(valid, exc_pos, spec.cap)
    high = jnp.zeros((spec.cap + 1,), jnp.uint32).at[pos].set(exc_val)[: spec.cap]
    gaps = low + (high << spec.width)
    ids = bpref.sorted_from_gaps(gaps, count, fill)
    return ids, count


def pack_bitmap(bits: jax.Array) -> jax.Array:
    """Dense 0/1 vector -> uint32 words (vertical width-1 packing)."""
    return bp.pack(bits.astype(jnp.uint32), 1)


def unpack_bitmap(words: jax.Array) -> jax.Array:
    return bp.unpack(words, 1).astype(bool)


# ---------------------------------------------------------------------------
# bucket ladders
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """Sparse-id buckets (ascending capacity) + dense fallback.

    ``s`` = chunk width (multiple of 1024).  ``floor_words`` is the dense
    fallback's wire size: s/32 for membership bitmaps (column phase), s for
    int32 candidate vectors (row phase) — the row phase therefore packs at
    far higher densities.  ``payload_width`` adds per-id payload words
    (packed parents) to each bucket's cost when deciding usability."""

    s: int
    specs: tuple[IdStreamSpec, ...]
    floor_words: int

    @classmethod
    def default(
        cls, s: int, floor_words: int | None = None, payload_width: int = 0
    ) -> "BucketLadder":
        floor = floor_words if floor_words is not None else s // 32
        caps: list[int] = []
        for frac in (256, 64, 16, 4):
            cap = max(s // frac, bpref.CHUNK)
            cap = min(cap, 1 << 16)
            wire = IdStreamSpec(cap).n_words + cap * payload_width // 32
            # only keep buckets that genuinely undercut the dense floor
            if cap < s and cap not in caps and wire < floor:
                caps.append(cap)
        return cls(s=s, specs=tuple(IdStreamSpec(c) for c in sorted(caps)), floor_words=floor)

    @property
    def n_branches(self) -> int:
        return len(self.specs) + 1  # + dense fallback

    def bucket_for(self, count: jax.Array, exc_count: jax.Array) -> jax.Array:
        """Smallest usable bucket index for this rank (before pmax)."""
        b = jnp.int32(len(self.specs))  # dense fallback
        for i in range(len(self.specs) - 1, -1, -1):
            ok = (count <= self.specs[i].cap) & (exc_count <= self.specs[i].exc_cap)
            b = jnp.where(ok, jnp.int32(i), b)
        return b

    def words_for_branch(self, i: int, payload_width: int = 0) -> int:
        if i < len(self.specs):
            return self.specs[i].n_words + self.specs[i].cap * payload_width // 32
        return self.floor_words


def _stream_stats(bits: jax.Array, s: int):
    """ids (s,), count, exception count of the gap stream (for bucketing)."""
    ids, count = bp.compact_ids(bits, s, fill=s)
    gaps = bpref.gaps_from_sorted(ids, count)
    exc_count = jnp.sum((gaps >> 16) > 0)
    return ids, count, exc_count


# ---------------------------------------------------------------------------
# compressed collectives
# ---------------------------------------------------------------------------


def allgather_membership(bits: jax.Array, axis, ladder: BucketLadder, group_size: int):
    """Compressed all-gather of a membership vector (paper's column phase).

    Every rank contributes an ``(s,)`` bool vector; returns the
    ``(group_size * s,)`` concatenation.  The transported representation is
    chosen per communicator group via pmax + lax.switch.
    """
    s = ladder.s
    ids, count, exc_count = _stream_stats(bits, s)
    bucket = jax.lax.pmax(ladder.bucket_for(count, exc_count), axis)

    def sparse_branch(spec: IdStreamSpec):
        def run(_):
            words, meta = pack_id_stream(ids, count, spec)
            g_words = jax.lax.all_gather(words, axis, tiled=True)
            g_meta = jax.lax.all_gather(meta, axis, tiled=True).reshape(group_size, 2)
            g_words = g_words.reshape(group_size, spec.n_words)
            u_ids, u_counts = jax.vmap(
                lambda w, m: unpack_id_stream(w, m, spec, fill=s)
            )(g_words, g_meta)
            # scatter memberships into the concatenated vector
            offs = (jnp.arange(group_size, dtype=jnp.int32) * s)[:, None]
            flat = jnp.where(u_ids < s, u_ids + offs, group_size * s).reshape(-1)
            out = jnp.zeros((group_size * s + 1,), bool).at[flat].set(True)
            return out[: group_size * s]

        return run

    def bitmap_branch(_):
        words = pack_bitmap(bits)
        g = jax.lax.all_gather(words, axis, tiled=True)
        return unpack_bitmap(g)

    branches = [sparse_branch(spec) for spec in ladder.specs] + [bitmap_branch]
    return jax.lax.switch(bucket, branches, operand=None)


def alltoall_min_candidates(
    prop: jax.Array,
    axis: str,
    ladder: BucketLadder,
    group_size: int,
    parent_width: int,
):
    """Compressed all-to-all + min-reduce of candidate parents (row phase).

    ``prop``: (group_size, s) int32 — proposal subchunk per destination rank
    (INF = no candidate).  Returns (s,) int32 min over all senders of the
    subchunk addressed to this rank.  Ids are delta+patched-packed; parent
    payloads are packed at the static ``parent_width`` class.
    """
    s = ladder.s
    c = group_size
    bits = prop < INF
    ids, counts = jax.vmap(lambda b: bp.compact_ids(b, s, fill=s))(bits)
    gaps = jax.vmap(bpref.gaps_from_sorted)(ids, counts)
    exc_counts = jnp.sum((gaps >> 16) > 0, axis=1)
    my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))
    bucket = jax.lax.pmax(my_bucket, axis)

    def sparse_branch(spec: IdStreamSpec):
        def run(_):
            def pack_one(ids_d, count_d, prop_d):
                w, m = pack_id_stream(ids_d, count_d, spec)
                par = prop_d[jnp.clip(ids_d[: spec.cap], 0, s - 1)]
                par = jnp.where(jnp.arange(spec.cap) < count_d, par, 0)
                pw = bp.pack(par.astype(jnp.uint32), parent_width)
                return w, m, pw

            idw, meta, parw = jax.vmap(pack_one)(ids, counts, prop)
            r_idw = jax.lax.all_to_all(idw, axis, 0, 0, tiled=True).reshape(
                c, spec.n_words
            )
            r_meta = jax.lax.all_to_all(meta, axis, 0, 0, tiled=True).reshape(c, 2)
            r_parw = jax.lax.all_to_all(parw, axis, 0, 0, tiled=True).reshape(
                c, spec.cap * parent_width // 32
            )

            def unpack_one(w, m, pw):
                u_ids, u_count = unpack_id_stream(w, m, spec, fill=s)
                par = bp.unpack(pw, parent_width).astype(jnp.int32)
                valid = jnp.arange(spec.cap) < u_count
                seg = jnp.where(valid, u_ids[: spec.cap], s)
                val = jnp.where(valid, par, INF)
                return seg, val

            segs, vals = jax.vmap(unpack_one)(r_idw, r_meta, r_parw)
            red = jax.ops.segment_min(vals.reshape(-1), segs.reshape(-1), num_segments=s + 1)
            return red[:s].astype(jnp.int32)

        return run

    def dense_branch(_):
        recv = jax.lax.all_to_all(prop, axis, 0, 0, tiled=True).reshape(c, s)
        return jnp.min(recv, axis=0)

    branches = [sparse_branch(spec) for spec in ladder.specs] + [dense_branch]
    return jax.lax.switch(bucket, branches, operand=None)


# ---------------------------------------------------------------------------
# beyond-paper: quantized all-reduce for data-parallel gradient sync
# ---------------------------------------------------------------------------


def allreduce_int8(x: jax.Array, axis, group_size: int) -> jax.Array:
    """Two-phase int8-quantized all-reduce (reduce_scatter + all_gather).

    Both wire transfers carry int8 payloads + f32 scales per 128 values —
    ~3.8x fewer bytes than an fp32 ring all-reduce.  Lossy; pair with error
    feedback (optim/grad_compress.py).  ``x`` length must divide by
    group_size * 128.
    """
    n = x.shape[0]
    assert n % (group_size * quant.GROUP) == 0, n
    # phase 1: quantize my shard-chunks, exchange, locally sum my chunk
    chunks = x.reshape(group_size, n // group_size)
    q, sc = jax.vmap(quant.quantize)(chunks)
    q_r = jax.lax.all_to_all(q, axis, 0, 0, tiled=True).reshape(group_size, -1)
    sc_r = jax.lax.all_to_all(sc, axis, 0, 0, tiled=True).reshape(group_size, -1)
    partial = jnp.sum(jax.vmap(quant.dequantize)(q_r, sc_r), axis=0)
    # phase 2: quantize reduced chunk, all-gather
    q2, sc2 = quant.quantize(partial)
    q_all = jax.lax.all_gather(q2, axis, tiled=True)
    sc_all = jax.lax.all_gather(sc2, axis, tiled=True)
    return quant.dequantize(q_all, sc_all).reshape(x.shape)
