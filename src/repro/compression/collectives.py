"""Compatibility shim — the compressed collectives moved to :mod:`repro.comm`.

The wire-format logic (PFOR16 id streams, bitmaps, bucket ladders, the
pmax + lax.switch adaptive dispatch) now lives in the unified communication
plane::

    repro.comm.formats      # IdStreamSpec, pack/unpack, WireFormat objects
    repro.comm.ladder       # BucketLadder (threshold-pruned)
    repro.comm.engine       # AdaptiveExchange
    repro.comm.collectives  # allgather_membership / alltoall_min_candidates
                            # / allreduce_int8, byte-accounted via CommStats

This module re-exports the public names so existing imports keep working.
"""

from __future__ import annotations

from repro.comm.collectives import (  # noqa: F401
    allgather_membership,
    allreduce_int8,
    alltoall_min_candidates,
)
from repro.comm.formats import (  # noqa: F401
    INF,
    IdStreamSpec,
    pack_bitmap,
    pack_id_stream,
    unpack_bitmap,
    unpack_id_stream,
)
from repro.comm.ladder import BucketLadder, stream_stats as _stream_stats  # noqa: F401
