"""Communication-compression substrate (paper §5).

Two layers:

* :mod:`repro.compression.codecs` — host (numpy) *variable-length* codecs, the
  faithful analog of the paper's S4-BP128 / VByte / bitmap comparison
  (Tables 5.4/5.5).  Used by benchmarks and by the host-side Graph500 driver.
* :mod:`repro.compression.threshold` — the §5.4.3 break-even model consulted
  by the bucket ladders in :mod:`repro.comm`.

The *static-shape* in-graph collectives moved to :mod:`repro.comm` (the
unified communication plane); ``repro.compression.collectives`` and
``repro.compression.registry`` remain as import-compatible shims.  The
in-graph bit-packing itself lives in :mod:`repro.kernels.bitpack`
(Pallas TPU kernel + jnp oracle).

NOTE: ``registry``/``collectives`` are intentionally NOT imported here —
they pull in :mod:`repro.comm`, which imports back into this package
(codecs, threshold); eager imports would make package init order circular.
``from repro.compression import registry`` still works as a submodule
import.
"""

from repro.compression import codecs, threshold  # noqa: F401
