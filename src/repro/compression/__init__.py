"""Communication-compression substrate (paper §5).

Two layers:

* :mod:`repro.compression.codecs` — host (numpy) *variable-length* codecs, the
  faithful analog of the paper's S4-BP128 / VByte / bitmap comparison
  (Tables 5.4/5.5).  Used by benchmarks and by the host-side Graph500 driver.
* :mod:`repro.compression.collectives` — *static-shape* compressed collectives
  for use inside compiled JAX programs (shard_map).  XLA has no ``v``-variant
  collectives, so runtime variable sizing is replaced by bucketed, globally
  uniform (count-capacity, bit-width) classes — see DESIGN.md §3.

The in-graph bit-packing itself lives in :mod:`repro.kernels.bitpack`
(Pallas TPU kernel + jnp oracle).
"""

from repro.compression import codecs, registry, threshold  # noqa: F401
