"""DEPRECATED — this package was absorbed by :mod:`repro.comm`.

The host codecs live at :mod:`repro.comm.codecs`, the §5.4.3 break-even
model at :mod:`repro.comm.threshold`, the codec factory inside
:mod:`repro.comm.registry`, and the compressed collectives (with their
wire formats and bucket ladders) across :mod:`repro.comm.collectives` /
:mod:`repro.comm.formats` / :mod:`repro.comm.ladder`.

This module is the one remaining shim: importing it warns, and the old
submodule paths (``repro.compression.codecs`` etc.) resolve to their
:mod:`repro.comm` homes so external imports keep working one release
longer.  In-repo code imports :mod:`repro.comm` directly.
"""

from __future__ import annotations

import sys
import types
import warnings

from repro import comm
from repro.comm import codecs, threshold  # noqa: F401
from repro.comm import registry as _comm_registry

warnings.warn(
    "repro.compression is deprecated; import repro.comm "
    "(codecs / threshold / registry / collectives) instead",
    DeprecationWarning,
    stacklevel=2,
)

# the retired registry shim renamed the factory entry points; keep those
# aliases alive on a proxy module so its old spelling
# (``registry.available()`` / ``registry.register()``) survives too
registry = types.ModuleType(f"{__name__}.registry")
registry.__dict__.update(
    # keep the proxy's own module identity (__name__/__spec__/__loader__
    # etc.) so reload/introspection does not misattribute it to the real
    # module it mirrors
    {k: v for k, v in _comm_registry.__dict__.items() if not k.startswith("__")}
)
registry.__doc__ = _comm_registry.__doc__
registry.make_codec = _comm_registry.make_codec
registry.available = _comm_registry.available_codecs
registry.register = _comm_registry.register_codec

for _name, _mod in (
    ("codecs", codecs),
    # the old collectives shim re-exported the formats/ladder names too;
    # the comm package root is the faithful superset
    ("collectives", comm),
    ("registry", registry),
    ("threshold", threshold),
):
    sys.modules[f"{__name__}.{_name}"] = _mod
del _name, _mod
