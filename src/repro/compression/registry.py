"""Codec factory (paper §5.3/§5.4: pluggable "Factory" integration).

The paper integrates three 3rd-party compression libraries behind a factory
object created *outside* the timed BFS kernel so that codec choice is a
config knob, and new codecs can be added without touching the BFS.  This
module is that factory.  ``make_codec`` is called once by the driver; the
returned codec object is passed by reference into the communication layer.
"""

from __future__ import annotations

from typing import Callable

from repro.compression import codecs

_REGISTRY: dict[str, Callable[[], codecs.Codec]] = {}


def register(name: str, factory: Callable[[], codecs.Codec]) -> None:
    if name in _REGISTRY:
        raise ValueError(f"codec {name!r} already registered")
    _REGISTRY[name] = factory


def make_codec(name: str) -> codecs.Codec:
    """Instantiate a codec by name (paper: Factory call before Kernel 2)."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; known: {sorted(_REGISTRY)}") from None


def available() -> list[str]:
    return sorted(_REGISTRY)


# Built-in codecs (the paper's comparison set, Table 5.4).
register("copy", codecs.Copy)
register("bp128", lambda: codecs.BP128(delta=False))
register("bp128d", lambda: codecs.BP128(delta=True))  # paper's choice: S4-BP128+delta
register("pfor", lambda: codecs.PFOR(delta=False))
register("pfor-delta", lambda: codecs.PFOR(delta=True))
register("vbyte", lambda: codecs.VByte(delta=False))
register("vbyte-delta", lambda: codecs.VByte(delta=True))
register("bitmap", codecs.Bitmap)
