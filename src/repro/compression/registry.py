"""Compatibility shim — the codec factory was absorbed into the unified
wire-plan registry at :mod:`repro.comm.registry`.

``make_codec`` / ``available`` / ``register`` keep their old names here;
new code should use ``repro.comm.registry`` directly (which also registers
the in-graph wire plans next to the host codecs).
"""

from __future__ import annotations

from repro.comm.registry import (  # noqa: F401
    available_codecs as available,
    make_codec,
    register_codec as register,
)
