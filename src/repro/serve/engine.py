"""Slot-based batched decode engine (continuous batching, greedy/temperature).

A fixed pool of B slots shares one (L, B, S, w) KV cache.  Requests are
assigned to free slots; every engine tick runs ONE jitted decode step for
the whole pool (active slots masked), so throughput is batch-limited, not
request-limited — the standard TPU serving shape (decode_32k cell lowers
exactly this step).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (p,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        cfg: tfm.TransformerConfig,
        params: Any,
        batch_slots: int = 8,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.cache = tfm.init_cache(cfg, batch_slots, max_seq)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.pending: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos)
        )
        self._next_tok = np.zeros(batch_slots, np.int32)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _assign(self) -> None:
        for i in range(self.b):
            if self.slot_req[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[i] = req
                # prefill by stepping through the prompt tokens (cache fill)
                self.pos[i] = 0
                self._next_tok[i] = req.prompt[0]
                req._prompt_cursor = 0  # type: ignore[attr-defined]

    def tick(self) -> int:
        """One engine iteration; returns number of active slots."""
        self._assign()
        active = [i for i in range(self.b) if self.slot_req[i] is not None]
        if not active:
            return 0
        toks = jnp.asarray(self._next_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.params, self.cache, toks, pos)
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
            sampled = jax.random.categorical(k, logits / self.temperature, axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        sampled = np.asarray(sampled, np.int32)

        for i in active:
            req = self.slot_req[i]
            cur = req._prompt_cursor  # type: ignore[attr-defined]
            self.pos[i] += 1
            if cur + 1 < len(req.prompt):  # still consuming the prompt
                req._prompt_cursor = cur + 1  # type: ignore[attr-defined]
                self._next_tok[i] = req.prompt[cur + 1]
                continue
            tok = int(sampled[i])
            req.out.append(tok)
            self._next_tok[i] = tok
            if len(req.out) >= req.max_new or self.pos[i] >= self.max_seq - 1:
                req.done = True
                self.slot_req[i] = None
                self.pos[i] = 0
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.pending:
                return
        raise RuntimeError("engine did not drain")
