"""Batched serving runtime for the LM archs (slot-based continuous batching)."""
