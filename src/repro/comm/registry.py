"""Unified wire-plan + codec registry.

Absorbs the old ``repro.compression.registry`` codec factory (paper
§5.3/§5.4: pluggable "Factory" integration — codec choice is a config
knob resolved *outside* the timed kernel) and adds its in-graph analog:
**wire plans**, keyed by exchange-mode name, that build the adaptive
column/row collectives for the distributed BFS.  New exchange patterns
(butterfly, hierarchical) plug in as additional wire plans rather than a
hand-rolled fourth collective.

**Traversal policies** (direction optimization, paper §3.1) are the third
registry axis: ``top_down`` / ``bottom_up`` / ``direction_opt``, defined in
:mod:`repro.core.traversal` and resolved here by name; **expansion
backends** (local block storage: ``coo`` / ``ell`` / ``hybrid``, defined in
:mod:`repro.core.expand`) are the fourth; **frontier algebras** (the
semiring axis: ``bfs`` / ``sssp`` / ``cc`` / ``pagerank``, defined in
:mod:`repro.core.algebra`) are the fifth.  A distributed traversal
configuration is an *algebra x policy x wire-plan x expansion* point, and
new exchange patterns (butterfly), block layouts (hybrid COO/ELL) or
vertex programs (a new semiring) slot in as combinations rather than
bespoke drivers.

Host codecs (variable-length, numpy — benchmarks and the host Graph500
driver) and wire plans (static-shape, in-graph) live in the same module so
there is exactly one place a representation can be registered.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.comm import butterfly
from repro.comm import collectives as cc
from repro.comm.engine import AdaptiveExchange
from repro.comm.formats import INF, BitmapParentFormat
from repro.comm.ladder import BucketLadder
from repro.comm import codecs

# ---------------------------------------------------------------------------
# host codec factory (paper §5.3 "Factory")
# ---------------------------------------------------------------------------

_CODECS: dict[str, Callable[[], codecs.Codec]] = {}


def register_codec(name: str, factory: Callable[[], codecs.Codec]) -> None:
    if name in _CODECS:
        raise ValueError(f"codec {name!r} already registered")
    _CODECS[name] = factory


def make_codec(name: str) -> codecs.Codec:
    """Instantiate a codec by name (paper: Factory call before Kernel 2)."""
    try:
        return _CODECS[name]()
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; known: {sorted(_CODECS)}") from None


def available_codecs() -> list[str]:
    return sorted(_CODECS)


# Built-in codecs (the paper's comparison set, Table 5.4).
register_codec("copy", codecs.Copy)
register_codec("bp128", lambda: codecs.BP128(delta=False))
register_codec("bp128d", lambda: codecs.BP128(delta=True))  # paper's choice: S4-BP128+delta
register_codec("pfor", lambda: codecs.PFOR(delta=False))
register_codec("pfor-delta", lambda: codecs.PFOR(delta=True))
register_codec("vbyte", lambda: codecs.VByte(delta=False))
register_codec("vbyte-delta", lambda: codecs.VByte(delta=True))
register_codec("bitmap", codecs.Bitmap)


# ---------------------------------------------------------------------------
# wire plans (in-graph exchange modes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WirePlan:
    """Builders for one exchange mode's column/row collectives.

    Every builder takes a ``b`` keyword — the number of multi-source
    frontier *planes* the exchange carries — and the built callables are
    plane-batched: ``build_column(s, axis, group_size, *, b, policy, stats,
    phase)`` returns ``fn(bits (b, s) bool) -> (b, group_size*s) bool``;
    ``build_row(s, axis, group_size, n_c, parent_width, *, b, ...)``
    returns ``fn(prop (b, group_size, s) i32) -> (b, s) i32`` (combined
    over senders per plane; ``n_c`` is the column-slice width, which sizes
    the packed parent payload).  Row builders additionally take an ``alg``
    keyword — the :class:`repro.core.algebra.FrontierAlgebra` whose
    payload/combine the wire carries: id payloads localize/re-globalize
    against ``n_c``, value payloads travel as-is at the algebra's payload
    width, and sum-reduce algebras collapse every plan to the dense int32
    exchange with the algebra's add-combine (``alg=None`` keeps the
    historical min-parent wire bit-for-bit).  At ``b == 1`` the wire is byte-identical to
    the single-source exchange; at ``b > 1`` all planes share one bucket
    consensus and one collective pair per exchange, with id-stream
    sidebands packed one word per plane (the shared-header amortization).

    The bottom-up (pull) traversal direction adds two more exchange shapes:
    ``build_row_bu(s, axis, group_size, n_c, parent_width, ...)`` returns
    ``fn(prop (b, group_size, s) i32 column-LOCAL candidates) -> (b, s)
    i32`` (global parents, min over senders), and ``build_unreached(s,
    axis, group_size, ...)`` returns ``fn(bits (b, s) bool) ->
    (b, group_size*s) bool`` — the unreached-membership all-gather over the
    grid row that replaces the candidate id streams at dense levels.
    """

    name: str
    build_column: Callable
    build_row: Callable
    build_row_bu: Callable
    build_unreached: Callable


_WIRE_PLANS: dict[str, WirePlan] = {}


def register_wire_plan(plan: WirePlan) -> None:
    if plan.name in _WIRE_PLANS:
        raise ValueError(f"wire plan {plan.name!r} already registered")
    _WIRE_PLANS[plan.name] = plan


def wire_plan(name: str) -> WirePlan:
    try:
        return _WIRE_PLANS[name]
    except KeyError:
        raise KeyError(
            f"unknown wire plan {name!r}; known: {sorted(_WIRE_PLANS)}"
        ) from None


def available_wire_plans() -> list[str]:
    return sorted(_WIRE_PLANS)


def _raw_column(s, axis, group_size, *, b=1, policy=None, stats=None,
                phase="bfs/column"):
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if b == 1:
        return lambda bits: cc.gather_raw_ids(ex, bits[0])[None]
    return lambda bits: cc.gather_raw_ids_planes(ex, bits)


def _bitmap_column(s, axis, group_size, *, b=1, policy=None, stats=None,
                   phase="bfs/column"):
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if b == 1:
        return lambda bits: cc.gather_bitmap(ex, bits[0])[None]
    return lambda bits: cc.gather_bitmap_planes(ex, bits)


def _auto_column(s, axis, group_size, *, b=1, policy=None, stats=None,
                 phase="bfs/column"):
    ladder = BucketLadder.default(s, policy=policy)
    if b == 1:
        return lambda bits: cc.allgather_membership(
            bits[0], axis, ladder, group_size, stats=stats, phase=phase
        )[None]
    return lambda bits: cc.allgather_membership_planes(
        bits, axis, ladder, group_size, stats=stats, phase=phase
    )


def _sum_algebra(alg) -> bool:
    """Sum-reduce algebras bypass the min-merge sparse machinery: their
    candidates are dense partial sums, so every row wire degenerates to the
    dense int32 exchange with the algebra's add-combine."""
    return alg is not None and alg.reduce == "sum"


def _localize_n_c(alg, n_c):
    """Column-slice width for payload localization, or None when the
    payload is a value (already global) rather than a source id."""
    return n_c if alg is None or alg.payload_is_id else None


def _dense_row(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row", alg=None,
):
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if _sum_algebra(alg):
        return lambda prop: cc.alltoall_dense_combine_planes(ex, prop, alg)
    if b == 1:
        return lambda prop: cc.alltoall_dense_min(ex, prop[0])[None]
    return lambda prop: cc.alltoall_dense_min_planes(ex, prop)


def _auto_row(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row", alg=None,
):
    if _sum_algebra(alg):
        return _dense_row(
            s, axis, group_size, n_c, parent_width, b=b,
            policy=policy, stats=stats, phase=phase, alg=alg,
        )
    # the row phase's dense fallback is a 32-bit candidate vector -> its own
    # (deeper) ladder, with the parent payload priced into every bucket; the
    # payload packs COLUMN-LOCAL offsets (the receiver re-globalizes from the
    # all-to-all row index), so parent_width = class(n_c) is lossless.  For
    # value algebras the payload is already global (``n_c=None`` disables the
    # localize/re-globalize pair) and parent_width is the value class.
    ladder = BucketLadder.default(
        s, floor_words=s, payload_width=parent_width, policy=policy
    )
    loc = _localize_n_c(alg, n_c)
    if b == 1:
        return lambda prop: cc.alltoall_min_candidates(
            prop[0], axis, ladder, group_size, stats=stats, phase=phase, n_c=loc
        )[None]
    return lambda prop: cc.alltoall_min_candidates_planes(
        prop, axis, ladder, group_size, stats=stats, phase=phase, n_c=loc
    )


def _btfly_row(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row", alg=None,
):
    """log2(C)-stage butterfly push row phase (merge + re-bucket per hop)."""
    return butterfly.build_row_exchange(
        s, axis, group_size, n_c, b=b, to_global=False,
        policy=policy, stats=stats, phase=phase, alg=alg,
    )


def _btfly_row_bu(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row-pull", alg=None,
):
    """Butterfly pull row phase: globalize column-local candidates, then the
    same staged min-merge as the push direction."""
    return butterfly.build_row_exchange(
        s, axis, group_size, n_c, b=b, to_global=True,
        policy=policy, stats=stats, phase=phase, alg=alg,
    )


def _btfly_unreached(
    s, axis, group_size, *, b=1, policy=None, stats=None, phase="bfs/unreached"
):
    return butterfly.build_unreached_gather(
        s, axis, group_size, b=b, policy=policy, stats=stats, phase=phase
    )


def _dense_row_bu(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row-pull", alg=None,
):
    """Baseline pull row exchange: globalize candidates, dense int32 wire."""
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if _sum_algebra(alg):
        return lambda prop: cc.alltoall_dense_combine_planes(ex, prop, alg)
    localize = alg is None or alg.payload_is_id

    def run(prop):
        glob = prop
        if localize:
            j = jax.lax.axis_index(axis)
            glob = jnp.where(prop < INF, j * n_c + prop, INF)
        if b == 1:
            return cc.alltoall_dense_min(ex, glob[0])[None]
        return cc.alltoall_dense_min_planes(ex, glob)

    return run


def _bitmap_row_bu(
    s, axis, group_size, n_c, parent_width, *, b=1, policy=None, stats=None,
    phase="bfs/row-pull", alg=None,
):
    """Compressed pull row exchange: found-bitmap + bit-packed parents."""
    if _sum_algebra(alg) or parent_width >= 32:
        # width-32 payloads (value algebras, huge n_c) would not undercut
        # the dense vector; sum candidates are dense by nature
        return _dense_row_bu(
            s, axis, group_size, n_c, parent_width, b=b,
            policy=policy, stats=stats, phase=phase, alg=alg,
        )
    fmt = BitmapParentFormat(s, parent_width)
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    loc = _localize_n_c(alg, n_c)
    if b == 1:
        return lambda prop: cc.alltoall_bitmap_min(ex, prop[0], fmt, loc)[None]
    return lambda prop: cc.alltoall_bitmap_min_planes(ex, prop, fmt, loc)


def _raw_unreached(s, axis, group_size, *, b=1, policy=None, stats=None,
                   phase="bfs/unreached"):
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if b == 1:
        return lambda bits: cc.gather_raw_ids(ex, bits[0])[None]
    return lambda bits: cc.gather_raw_ids_planes(ex, bits)


def _bitmap_unreached(s, axis, group_size, *, b=1, policy=None, stats=None,
                      phase="bfs/unreached"):
    ex = AdaptiveExchange(phase, axis, group_size, None, stats, planes=b)
    if b == 1:
        return lambda bits: cc.gather_bitmap(ex, bits[0])[None]
    return lambda bits: cc.gather_bitmap_planes(ex, bits)


register_wire_plan(
    WirePlan("raw", _raw_column, _dense_row, _dense_row_bu, _raw_unreached)
)
register_wire_plan(
    WirePlan("bitmap", _bitmap_column, _dense_row, _bitmap_row_bu, _bitmap_unreached)
)
register_wire_plan(
    WirePlan("auto", _auto_column, _auto_row, _bitmap_row_bu, _bitmap_unreached)
)
# ButterFly BFS (arXiv:2103.13577): adaptive column gather + log2(C)-stage
# butterfly row/unreached exchanges that re-compress the merged stream per hop
register_wire_plan(
    WirePlan("btfly", _auto_column, _btfly_row, _btfly_row_bu, _btfly_unreached)
)


# ---------------------------------------------------------------------------
# traversal policies (direction optimization, paper §3.1)
# ---------------------------------------------------------------------------

_TRAVERSALS: dict[str, Any] = {}


def register_traversal(policy: Any) -> None:
    """Register a traversal policy object (must expose ``.name``)."""
    if policy.name in _TRAVERSALS:
        raise ValueError(f"traversal policy {policy.name!r} already registered")
    _TRAVERSALS[policy.name] = policy


def _ensure_builtin_traversals() -> None:
    if not _TRAVERSALS:
        # registers top_down / bottom_up / direction_opt on import
        import repro.core.traversal  # noqa: F401


def traversal(name: str) -> Any:
    """Resolve a traversal policy by name (lazy-imports the built-ins)."""
    _ensure_builtin_traversals()
    try:
        return _TRAVERSALS[name]
    except KeyError:
        raise KeyError(
            f"unknown traversal policy {name!r}; known: {sorted(_TRAVERSALS)}"
        ) from None


def available_traversals() -> list[str]:
    _ensure_builtin_traversals()
    return sorted(_TRAVERSALS)


# ---------------------------------------------------------------------------
# frontier algebras (the semiring axis: bfs / sssp / cc / pagerank)
# ---------------------------------------------------------------------------

_ALGEBRAS: dict[str, Any] = {}


def register_algebra(alg: Any) -> None:
    """Register a frontier algebra object (must expose ``.name``)."""
    if alg.name in _ALGEBRAS:
        raise ValueError(f"frontier algebra {alg.name!r} already registered")
    _ALGEBRAS[alg.name] = alg


def _ensure_builtin_algebras() -> None:
    if not _ALGEBRAS:
        # registers bfs / sssp / cc / pagerank on import
        import repro.core.algebra  # noqa: F401


def algebra(name: str) -> Any:
    """Resolve a frontier algebra by name (lazy-imports the built-ins)."""
    _ensure_builtin_algebras()
    try:
        return _ALGEBRAS[name]
    except KeyError:
        raise KeyError(
            f"unknown frontier algebra {name!r}; known: {sorted(_ALGEBRAS)}"
        ) from None


def available_algebras() -> list[str]:
    _ensure_builtin_algebras()
    return sorted(_ALGEBRAS)


# ---------------------------------------------------------------------------
# local-expansion backends (hybrid COO/ELL block storage)
# ---------------------------------------------------------------------------

_EXPANSIONS: dict[str, Any] = {}


def register_expansion(backend: Any) -> None:
    """Register a local-expansion backend object (must expose ``.name``)."""
    if backend.name in _EXPANSIONS:
        raise ValueError(f"expansion backend {backend.name!r} already registered")
    _EXPANSIONS[backend.name] = backend


def _ensure_builtin_expansions() -> None:
    if not _EXPANSIONS:
        # registers coo / ell / hybrid on import
        import repro.core.expand  # noqa: F401


def expansion(name: str) -> Any:
    """Resolve an expansion backend by name (lazy-imports the built-ins)."""
    _ensure_builtin_expansions()
    try:
        return _EXPANSIONS[name]
    except KeyError:
        raise KeyError(
            f"unknown expansion backend {name!r}; known: {sorted(_EXPANSIONS)}"
        ) from None


def available_expansions() -> list[str]:
    _ensure_builtin_expansions()
    return sorted(_EXPANSIONS)
