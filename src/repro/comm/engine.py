"""AdaptiveExchange: one engine behind every adaptive collective.

Generalizes the bucket-ladder + ``pmax`` group-consensus + ``lax.switch``
dispatch that the BFS column and row collectives used to hand-roll
separately, and funnels *every* collective primitive through CommStats
byte accounting:

* :meth:`dispatch` — per-rank bucket choice (from the ladder) is made
  group-uniform with a recorded ``pmax``, then ``lax.switch`` runs the
  branch whose collective carries exactly that bucket's words.  A
  single-branch exchange (empty ladder, or a fixed-format plan like the
  int8 gradient all-reduce) skips the consensus entirely — no dead
  all-reduce in the HLO.
* :meth:`all_gather` / :meth:`all_to_all` / :meth:`pmax` / :meth:`psum` /
  :meth:`ppermute` — thin wrappers over ``jax.lax`` that record the
  result-shape bytes of the op they emit, so CommStats entries correspond
  1:1 with the collective ops the dry-run roofline parses out of HLO.

Every record carries two byte counts: ``nbytes`` (result-shape bytes, the
HLO-parity convention ``compare_comm_stats`` checks) and ``moved_bytes``
(what actually crosses a link).  They differ exactly where the HLO operand
over-counts traffic: identity ``ppermute`` pairs (the 2D transpose always
contains self-sends), the own-chunk share of a gather/all-to-all, and the
ring all-reduce's 2(g-1)/g volume.

Recording happens at trace time; every entry's key is static, so
retracing is idempotent (see :mod:`repro.comm.stats`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

from repro.comm.ladder import BucketLadder
from repro.comm.stats import CommStats, aval_bytes

CONSENSUS = "consensus"  # fmt label of the bucket-choice all-reduce


@dataclasses.dataclass(frozen=True)
class AdaptiveExchange:
    """One adaptive exchange site: phase name, mesh axis, ladder, stats."""

    phase: str  # logical zone, e.g. "bfs/column"
    axis: Any  # mesh axis name or tuple of names
    group_size: int
    ladder: BucketLadder | None = None  # None -> single fixed format
    stats: CommStats | None = None
    #: number of multi-source frontier planes riding this site's payloads.
    #: With planes > 1 every payload collective is attributed per plane under
    #: sub-zones ``{phase}@p{k}`` (the plane shares divide exactly — every
    #: plane contributes the same word count), while the bucket-consensus
    #: all-reduce stays under the base phase: ONE consensus round serves all
    #: B planes, which is precisely the amortization the ledger must show.
    planes: int = 1

    # -- recording collective primitives ------------------------------------

    def _rec(self, fmt: str, kind: str, part: str, out: jax.Array,
             moved: int | None = None, per_plane: bool = True) -> None:
        if self.stats is None:
            return
        if self.planes > 1 and per_plane:
            nbytes = aval_bytes(out)
            assert nbytes % self.planes == 0, (self.phase, nbytes, self.planes)
            share = nbytes // self.planes
            for k in range(self.planes):
                m = None
                if moved is not None:
                    m = moved // self.planes
                    if k == self.planes - 1:  # keep the moved total exact
                        m += moved - self.planes * (moved // self.planes)
                self.stats.record(f"{self.phase}@p{k}", fmt, kind, part, share,
                                  moved_bytes=m)
        else:
            self.stats.record_aval(self.phase, fmt, kind, part, out,
                                   moved_bytes=moved)

    def _peer_share(self, out: jax.Array) -> int:
        """Result bytes minus the own chunk (gathers/all-to-alls keep 1/g)."""
        return aval_bytes(out) * (self.group_size - 1) // self.group_size

    def all_gather(self, x: jax.Array, *, fmt: str, part: str = "words") -> jax.Array:
        out = jax.lax.all_gather(x, self.axis, tiled=True)
        self._rec(fmt, "all-gather", part, out, moved=self._peer_share(out))
        return out

    def all_to_all(self, x: jax.Array, *, fmt: str, part: str = "words") -> jax.Array:
        out = jax.lax.all_to_all(x, self.axis, 0, 0, tiled=True)
        self._rec(fmt, "all-to-all", part, out, moved=self._peer_share(out))
        return out

    def pmax(self, x: jax.Array, *, fmt: str = CONSENSUS, part: str = "bucket") -> jax.Array:
        out = jax.lax.pmax(x, self.axis)
        # one consensus serves every plane: never split per plane
        self._rec(fmt, "all-reduce", part, out,
                  moved=2 * self._peer_share(out), per_plane=False)
        return out

    def pmin(self, x: jax.Array, *, fmt: str = CONSENSUS, part: str = "bucket") -> jax.Array:
        out = jax.lax.pmin(x, self.axis)
        # consensus-shaped like pmax (the SSSP window floor rides this)
        self._rec(fmt, "all-reduce", part, out,
                  moved=2 * self._peer_share(out), per_plane=False)
        return out

    def psum(self, x: jax.Array, *, fmt: str, part: str = "value") -> jax.Array:
        out = jax.lax.psum(x, self.axis)
        self._rec(fmt, "all-reduce", part, out, moved=2 * self._peer_share(out))
        return out

    def ppermute(self, x: jax.Array, perm, *, fmt: str, part: str = "words") -> jax.Array:
        out = jax.lax.ppermute(x, self.axis, perm)
        # identity pairs (src == dst) emit full HLO operand bytes but move
        # nothing; ranks outside ``perm`` receive zeros without traffic
        n_moved = sum(1 for src, dst in perm if src != dst)
        self._rec(fmt, "collective-permute", part, out,
                  moved=aval_bytes(out) * n_moved // self.group_size)
        return out

    # -- adaptive dispatch ----------------------------------------------------

    def dispatch(
        self,
        local_bucket: jax.Array | None,
        branches: Sequence[Callable[[Any], jax.Array]],
    ) -> jax.Array:
        """Group-consensus branch selection.

        ``branches`` is index-aligned with the ladder's sparse formats,
        dense fallback last.  ``local_bucket`` is this rank's smallest
        usable bucket (ignored when only one branch exists).
        """
        if len(branches) == 1:
            return branches[0](None)
        assert local_bucket is not None
        bucket = self.pmax(local_bucket)
        return jax.lax.switch(bucket, list(branches), operand=None)
