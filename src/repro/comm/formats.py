"""Wire formats: every static-shape representation a collective can carry.

XLA collectives are static-shape — there is no ``MPI_Allgatherv``.  The
paper's variable-length compressed exchange maps to the accelerator as a
set of fixed-geometry *wire formats*, each knowing its word count at trace
time and packing/unpacking payloads losslessly:

* :class:`IdStreamFormat` — delta (gap) coding + vertical 16-bit binary
  packing with *patched exceptions* (Zukowski's PFOR, static exception
  capacity) — the paper's S4-BP128+delta in the lane-aligned layout of
  :mod:`repro.kernels.bitpack`; optionally carries a bit-packed per-id
  payload (candidate parents in the BFS row phase).
* :class:`BitmapFormat` — dense width-1 membership bitmap, the always-valid
  fallback (the paper's "adaptive data representation" row, §3.1).
* :class:`RawIdFormat` — uncompressed 32-bit id list at full capacity (the
  paper's Baseline).
* :class:`DenseFormat` — uncompressed dense value vector (row-phase
  fallback).
* :class:`BitmapParentFormat` — found-bitmap + bit-packed parent payload,
  the bottom-up (pull) row exchange of the direction-optimized traversal.
* :class:`Int8Format` — block-quantized int8 payload + f32 scales per 128
  values (beyond-paper: gradient/feature wire format).

Every format exposes static geometry (``data_words``/``meta_words``/
``wire_bytes``) consumed by the bucket ladder, CommStats, and the
benchmarks — the single source of truth for bytes-on-the-wire.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.kernels.bitpack import ops as bp
from repro.kernels.bitpack import ref as bpref
from repro.kernels.quant import ref as quant

INF = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# static-shape patched id-stream codec (PFOR-16 with exception slots)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IdStreamSpec:
    """Static geometry of one packed sorted-id stream.

    cap: id capacity (multiple of 1024, <= 65536 so positions fit 16 bits).
    width: low-bits width (16 covers the paper's measured 15-bit entropy).
    """

    cap: int
    width: int = 16

    def __post_init__(self):
        assert self.cap % bpref.CHUNK == 0 and self.cap <= 1 << 16, self.cap
        assert self.width in (8, 16), self.width

    @property
    def exc_cap(self) -> int:
        return self.cap // 8

    @property
    def n_words(self) -> int:
        return self.cap * self.width // 32 + self.exc_cap


def pack_id_stream(ids: jax.Array, count: jax.Array, spec: IdStreamSpec):
    """Sorted ids (padded, int32) + count -> (words (n_words,), meta (2,)).

    meta = (count, exception_count).  Values must satisfy count <= spec.cap
    and exception_count <= spec.exc_cap — guaranteed by bucket selection.
    """
    ids = ids[: spec.cap]
    gaps = bpref.gaps_from_sorted(ids, count)  # uint32, zeros beyond count
    mask = jnp.uint32((1 << spec.width) - 1)
    low = gaps & mask
    high = gaps >> spec.width
    exc_pos, exc_count = bp.compact_ids(high > 0, spec.exc_cap, fill=spec.cap)
    exc_val = jnp.where(
        jnp.arange(spec.exc_cap) < exc_count,
        high[jnp.clip(exc_pos, 0, spec.cap - 1)],
        0,
    ).astype(jnp.uint32)
    exc_words = exc_pos.astype(jnp.uint32) | (exc_val << 16)
    low_words = bp.pack(low, spec.width)
    words = jnp.concatenate([low_words, exc_words])
    meta = jnp.stack([count.astype(jnp.int32), exc_count.astype(jnp.int32)])
    return words, meta


def unpack_id_stream(words: jax.Array, meta: jax.Array, spec: IdStreamSpec, fill: int):
    """Inverse of :func:`pack_id_stream` -> (ids (cap,) int32, count)."""
    count, exc_count = meta[0], meta[1]
    n_low = spec.cap * spec.width // 32
    low = bp.unpack(words[:n_low], spec.width)
    exc_words = words[n_low:]
    exc_pos = (exc_words & jnp.uint32(0xFFFF)).astype(jnp.int32)
    exc_val = exc_words >> 16
    valid = jnp.arange(spec.exc_cap) < exc_count
    pos = jnp.where(valid, exc_pos, spec.cap)
    high = jnp.zeros((spec.cap + 1,), jnp.uint32).at[pos].set(exc_val)[: spec.cap]
    gaps = low + (high << spec.width)
    ids = bpref.sorted_from_gaps(gaps, count, fill)
    return ids, count


def pack_bitmap(bits: jax.Array) -> jax.Array:
    """Dense 0/1 vector -> uint32 words (vertical width-1 packing)."""
    return bp.pack(bits.astype(jnp.uint32), 1)


def unpack_bitmap(words: jax.Array) -> jax.Array:
    return bp.unpack(words, 1).astype(bool)


# ---------------------------------------------------------------------------
# plane (multi-source batch) headers: B id streams under ONE wire header
# ---------------------------------------------------------------------------

#: bits of the packed plane header that hold the id count (counts reach
#: cap <= 2**16 inclusive, so 17 bits; the exception count, <= cap/8 <= 8192,
#: rides in the remaining 14 bits of a non-negative int32)
PLANE_COUNT_BITS = 17


def plane_meta_words(b: int) -> int:
    """Sideband words of ``b`` id streams sharing one exchange.

    A single stream keeps the legacy (count, exc_count) int32 pair; batched
    planes pack both counts of each plane into ONE word — the shared-header
    amortization of the multi-source exchange (half the sideband per source).
    """
    return 2 if b == 1 else b


def pack_plane_meta(counts: jax.Array, exc_counts: jax.Array) -> jax.Array:
    """Per-plane (count, exc_count) int32 pairs -> one packed word per plane."""
    return (counts | (exc_counts << PLANE_COUNT_BITS)).astype(jnp.int32)


def unpack_plane_meta(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_plane_meta` -> (counts, exc_counts)."""
    mask = (1 << PLANE_COUNT_BITS) - 1
    return words & mask, words >> PLANE_COUNT_BITS


def plane_wire_bytes(fmt, b: int) -> int:
    """Wire bytes of ``b`` frontier planes carried by one exchange of ``fmt``.

    Dense formats (bitmap, dense vector, found-bitmap + parents, raw ids)
    scale linearly — each plane pays its full geometry.  Id-stream formats
    amortize the header: ``b`` data payloads share a packed one-word-per-
    plane sideband instead of ``b`` two-word metas.  This is the single
    byte model the device collectives, the host replay benchmark, and the
    CI byte-model check all price plane exchanges with.
    """
    if b == 1:
        return fmt.wire_bytes
    if isinstance(fmt, IdStreamFormat):
        return 4 * (b * fmt.data_words + plane_meta_words(b))
    return b * fmt.wire_bytes


# ---------------------------------------------------------------------------
# wire-format objects
# ---------------------------------------------------------------------------


@runtime_checkable
class WireFormat(Protocol):
    """Static wire geometry of one exchange participant."""

    @property
    def name(self) -> str: ...

    @property
    def data_words(self) -> int: ...  # u32 payload words on the wire

    @property
    def meta_words(self) -> int: ...  # int32 sideband words (0 if none)

    @property
    def wire_bytes(self) -> int: ...  # total bytes per participant


@dataclasses.dataclass(frozen=True)
class BitmapFormat:
    """Width-1 dense membership bitmap over ``s`` vertices."""

    s: int

    @property
    def name(self) -> str:
        return "bitmap"

    @property
    def data_words(self) -> int:
        return self.s // 32

    @property
    def meta_words(self) -> int:
        return 0

    @property
    def wire_bytes(self) -> int:
        return 4 * self.data_words

    def pack(self, bits: jax.Array) -> jax.Array:
        return pack_bitmap(bits)

    def unpack(self, words: jax.Array) -> jax.Array:
        return unpack_bitmap(words)


@dataclasses.dataclass(frozen=True)
class IdStreamFormat:
    """Delta + PFOR16 packed sorted-id stream, optional bit-packed payload.

    The payload (``payload_width`` bits per id, 0 = none) rides in the same
    word vector as the id stream, so one collective moves both.
    """

    spec: IdStreamSpec
    payload_width: int = 0

    @property
    def name(self) -> str:
        return f"pfor{self.spec.width}[{self.spec.cap}]"

    @property
    def payload_words(self) -> int:
        return self.spec.cap * self.payload_width // 32

    @property
    def data_words(self) -> int:
        return self.spec.n_words + self.payload_words

    @property
    def meta_words(self) -> int:
        return 2

    @property
    def wire_bytes(self) -> int:
        return 4 * (self.data_words + self.meta_words)

    def pack(self, ids: jax.Array, count: jax.Array, payload: jax.Array | None = None):
        """ids (>= cap, sorted, padded) + count [+ payload (cap,)] -> words, meta."""
        words, meta = pack_id_stream(ids, count, self.spec)
        if self.payload_width:
            assert payload is not None
            payload = jnp.where(
                jnp.arange(self.spec.cap) < count, payload[: self.spec.cap], 0
            )
            pw = bp.pack(payload.astype(jnp.uint32), self.payload_width)
            words = jnp.concatenate([words, pw])
        return words, meta

    def unpack(self, words: jax.Array, meta: jax.Array, fill: int):
        """-> (ids (cap,) int32, count, payload (cap,) int32 | None)."""
        ids, count = unpack_id_stream(words[: self.spec.n_words], meta, self.spec, fill)
        payload = None
        if self.payload_width:
            payload = bp.unpack(words[self.spec.n_words :], self.payload_width).astype(
                jnp.int32
            )
        return ids, count, payload


@dataclasses.dataclass(frozen=True)
class BitmapParentFormat:
    """Found-bitmap + dense bit-packed parent payload (bottom-up row phase).

    The pull direction needs no id stream: every position of an owned chunk
    is described by one *found* bit (a frontier neighbor exists) plus a
    ``payload_width``-bit column-local parent id riding in the same word
    vector.  Wire cost is ``s/32 + s*payload_width/32`` words per chunk —
    cheaper than the 32-bit dense candidate vector whenever
    ``payload_width < 32``, independent of frontier density (which is the
    point: bottom-up runs at the dense levels where id streams stop
    paying).  The receiver rebuilds global parents as
    ``sender_col * n_c + local`` and min-reduces, which preserves the
    push direction's min-candidate winner exactly.
    """

    s: int
    payload_width: int

    def __post_init__(self):
        assert self.s % bpref.CHUNK == 0, self.s
        assert self.payload_width in bpref.B_CLASSES and self.payload_width < 32, (
            self.payload_width
        )

    @property
    def name(self) -> str:
        return f"bitmap+p{self.payload_width}"

    @property
    def data_words(self) -> int:
        return self.s // 32 + self.s * self.payload_width // 32

    @property
    def meta_words(self) -> int:
        return 0

    @property
    def wire_bytes(self) -> int:
        return 4 * self.data_words

    def pack(self, prop: jax.Array) -> jax.Array:
        """(s,) int32 column-local candidates (INF = none) -> wire words."""
        bits = prop < INF
        payload = jnp.where(bits, prop, 0).astype(jnp.uint32)
        return jnp.concatenate(
            [pack_bitmap(bits), bp.pack(payload, self.payload_width)]
        )

    def unpack(self, words: jax.Array) -> tuple[jax.Array, jax.Array]:
        """-> (found (s,) bool, local parent (s,) int32)."""
        bits = unpack_bitmap(words[: self.s // 32])
        local = bp.unpack(words[self.s // 32 :], self.payload_width).astype(jnp.int32)
        return bits, local


@dataclasses.dataclass(frozen=True)
class RawIdFormat:
    """Uncompressed 32-bit id list at full static capacity (paper Baseline)."""

    cap: int

    @property
    def name(self) -> str:
        return "raw-id"

    @property
    def data_words(self) -> int:
        return self.cap

    @property
    def meta_words(self) -> int:
        return 1  # the count

    @property
    def wire_bytes(self) -> int:
        return 4 * (self.data_words + self.meta_words)

    def pack(self, bits: jax.Array):
        ids, count = bp.compact_ids(bits, self.cap, fill=self.cap)
        return ids, count[None].astype(jnp.int32)

    def unpack(self, ids: jax.Array, meta: jax.Array, fill: int):
        valid = jnp.arange(self.cap) < meta[0]
        return jnp.where(valid & (ids < self.cap), ids, fill), meta[0]


@dataclasses.dataclass(frozen=True)
class DenseFormat:
    """Uncompressed dense value vector (row-phase fallback), int32."""

    s: int

    @property
    def name(self) -> str:
        return "dense-i32"

    @property
    def data_words(self) -> int:
        return self.s

    @property
    def meta_words(self) -> int:
        return 0

    @property
    def wire_bytes(self) -> int:
        return 4 * self.s


@dataclasses.dataclass(frozen=True)
class Int8Format:
    """Block-quantized int8 payload + one f32 scale per ``group`` values."""

    n: int  # values per participant
    group: int = quant.GROUP

    @property
    def name(self) -> str:
        return "int8"

    @property
    def data_words(self) -> int:
        return self.n // 4  # int8 payload measured in u32-word equivalents

    @property
    def meta_words(self) -> int:
        return self.n // self.group  # f32 scales

    @property
    def wire_bytes(self) -> int:
        return self.n + 4 * (self.n // self.group)

    def pack(self, x: jax.Array):
        return quant.quantize(x)

    def unpack(self, q: jax.Array, scales: jax.Array) -> jax.Array:
        return quant.dequantize(q, scales)
