"""The adaptive collectives, rebuilt on the AdaptiveExchange engine.

Paper Alg. 4 on the accelerator: the column phase (ALLGATHERV + compress)
and the row phase (ALLTOALLV + compress) both dispatch through
:class:`repro.comm.engine.AdaptiveExchange`; the representation on the
wire is one of the :mod:`repro.comm.formats` chosen per communicator group
by the bucket ladder.  The bottom-up (pull) traversal direction swaps the
row id-stream ALLTOALLV for :func:`alltoall_bitmap_min` — a found-bitmap +
bit-packed-parent exchange whose cost is density-independent.  The
butterfly wire plan's staged rounds (:mod:`repro.comm.butterfly`) go
through :func:`ppermute_min_block` / :func:`ppermute_membership_block` —
one adaptive partner-exchange per stage, re-bucketed on the merged stream.
The int8 gradient all-reduce (beyond-paper) is the degenerate
single-format case of the same engine.

Every collective reports its bytes through :class:`repro.comm.stats.CommStats`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.engine import AdaptiveExchange
from repro.comm.formats import (
    INF,
    BitmapFormat,
    BitmapParentFormat,
    DenseFormat,
    IdStreamFormat,
    Int8Format,
    RawIdFormat,
    pack_plane_meta,
    unpack_plane_meta,
)
from repro.comm.ladder import BucketLadder, stream_stats
from repro.comm.stats import CommStats
from repro.kernels.bitpack import ops as bp
from repro.kernels.bitpack import ref as bpref
from repro.kernels.quant import ref as quant


def _scatter_membership(u_ids: jax.Array, s: int, group_size: int) -> jax.Array:
    """(group, cap) gathered ids -> (group*s,) membership vector."""
    offs = (jnp.arange(group_size, dtype=jnp.int32) * s)[:, None]
    flat = jnp.where(u_ids < s, u_ids + offs, group_size * s).reshape(-1)
    out = jnp.zeros((group_size * s + 1,), bool).at[flat].set(True)
    return out[: group_size * s]


# ---------------------------------------------------------------------------
# column phase: membership all-gather
# ---------------------------------------------------------------------------


def gather_bitmap(ex: AdaptiveExchange, bits: jax.Array) -> jax.Array:
    """Dense width-1 bitmap all-gather of an (s,) membership vector."""
    fmt = BitmapFormat(bits.shape[0])
    return fmt.unpack(ex.all_gather(fmt.pack(bits), fmt=fmt.name))


def gather_raw_ids(ex: AdaptiveExchange, bits: jax.Array) -> jax.Array:
    """Uncompressed 32-bit id-list all-gather (the paper's Baseline)."""
    s = bits.shape[0]
    fmt = RawIdFormat(s)
    ids, meta = fmt.pack(bits)
    g_ids = ex.all_gather(ids, fmt=fmt.name).reshape(ex.group_size, s)
    g_meta = ex.all_gather(meta, fmt=fmt.name, part="meta").reshape(ex.group_size, 1)
    u_ids, _ = jax.vmap(lambda i, m: fmt.unpack(i, m, fill=s))(g_ids, g_meta)
    return _scatter_membership(u_ids, s, ex.group_size)


def allgather_membership(
    bits: jax.Array,
    axis,
    ladder: BucketLadder,
    group_size: int,
    *,
    stats: CommStats | None = None,
    phase: str = "bfs/column",
):
    """Adaptive all-gather of a membership vector (paper's column phase).

    Every rank contributes an ``(s,)`` bool vector; returns the
    ``(group_size * s,)`` concatenation.  The transported representation is
    chosen per communicator group via the engine's consensus dispatch.
    """
    s = ladder.s
    ex = AdaptiveExchange(phase, axis, group_size, ladder, stats)
    if not ladder.specs:  # degenerate ladder: dense bitmap only
        return ex.dispatch(None, [lambda _: gather_bitmap(ex, bits)])
    ids, count, exc_count = stream_stats(bits, s)

    def sparse_branch(fmt: IdStreamFormat):
        def run(_):
            words, meta = fmt.pack(ids, count)
            g_words = ex.all_gather(words, fmt=fmt.name).reshape(
                group_size, fmt.data_words
            )
            g_meta = ex.all_gather(meta, fmt=fmt.name, part="meta").reshape(
                group_size, 2
            )
            u_ids, _, _ = jax.vmap(lambda w, m: fmt.unpack(w, m, fill=s))(
                g_words, g_meta
            )
            return _scatter_membership(u_ids, s, group_size)

        return run

    branches = [sparse_branch(f) for f in ladder.formats()] + [
        lambda _: gather_bitmap(ex, bits)
    ]
    return ex.dispatch(ladder.bucket_for(count, exc_count), branches)


# ---------------------------------------------------------------------------
# plane-batched column phase: B membership planes per exchange
# ---------------------------------------------------------------------------


def gather_bitmap_planes(ex: AdaptiveExchange, bits: jax.Array) -> jax.Array:
    """Width-1 bitmap all-gather of ``(B, s)`` membership planes ->
    ``(B, group_size * s)``."""
    b, s = bits.shape
    fmt = BitmapFormat(s)
    words = jax.vmap(fmt.pack)(bits)  # (B, s/32)
    g = ex.all_gather(words, fmt=fmt.name).reshape(ex.group_size, b, -1)
    mem = jax.vmap(jax.vmap(fmt.unpack))(g)  # (group, B, s)
    return jnp.moveaxis(mem, 0, 1).reshape(b, -1)


def gather_raw_ids_planes(ex: AdaptiveExchange, bits: jax.Array) -> jax.Array:
    """Uncompressed 32-bit id-list all-gather of ``(B, s)`` planes."""
    b, s = bits.shape
    fmt = RawIdFormat(s)
    ids, meta = jax.vmap(fmt.pack)(bits)  # (B, s), (B, 1)
    g_ids = ex.all_gather(ids, fmt=fmt.name).reshape(ex.group_size, b, s)
    g_meta = ex.all_gather(meta.reshape(b), fmt=fmt.name, part="meta").reshape(
        ex.group_size, b, 1
    )
    u_ids, _ = jax.vmap(jax.vmap(lambda i, m: fmt.unpack(i, m, fill=s)))(
        g_ids, g_meta
    )  # (group, B, s)
    return jax.vmap(
        lambda u: _scatter_membership(u, s, ex.group_size)
    )(jnp.moveaxis(u_ids, 0, 1))


def allgather_membership_planes(
    bits: jax.Array,
    axis,
    ladder: BucketLadder,
    group_size: int,
    *,
    stats: CommStats | None = None,
    phase: str = "bfs/column",
):
    """Adaptive all-gather of ``(B, s)`` membership planes (batched column
    phase) -> ``(B, group_size * s)``.

    One bucket consensus (max over every plane on every rank) and one pair
    of collectives serve all B planes; sparse stages pack each plane's id
    stream at the shared bucket and the B (count, exc) pairs ride a packed
    one-word-per-plane sideband (:func:`repro.comm.formats.pack_plane_meta`).
    """
    b, s = bits.shape
    assert s == ladder.s, (s, ladder.s)
    ex = AdaptiveExchange(phase, axis, group_size, ladder, stats, planes=b)
    if not ladder.specs:
        return ex.dispatch(None, [lambda _: gather_bitmap_planes(ex, bits)])
    ids, counts, exc_counts = jax.vmap(lambda x: stream_stats(x, s))(bits)
    my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))

    def sparse_branch(fmt: IdStreamFormat):
        def run(_):
            words, meta = jax.vmap(fmt.pack)(ids, counts)  # (B, dw), (B, 2)
            pmeta = pack_plane_meta(meta[:, 0], meta[:, 1])  # (B,)
            g_words = ex.all_gather(words, fmt=fmt.name).reshape(
                group_size, b, fmt.data_words
            )
            g_meta = ex.all_gather(pmeta, fmt=fmt.name, part="meta").reshape(
                group_size, b
            )

            def unpack_one(w, m):
                c, e = unpack_plane_meta(m)
                u_ids, _, _ = fmt.unpack(w, jnp.stack([c, e]), fill=s)
                return u_ids

            u_ids = jax.vmap(jax.vmap(unpack_one))(g_words, g_meta)
            return jax.vmap(
                lambda u: _scatter_membership(u, s, group_size)
            )(jnp.moveaxis(u_ids, 0, 1))

        return run

    branches = [sparse_branch(f) for f in ladder.formats()] + [
        lambda _: gather_bitmap_planes(ex, bits)
    ]
    return ex.dispatch(my_bucket, branches)


# ---------------------------------------------------------------------------
# column phase: value-plane all-gather (non-BFS frontier algebras)
# ---------------------------------------------------------------------------


def gather_values_planes(ex: AdaptiveExchange, x: jax.Array) -> jax.Array:
    """Dense int32 all-gather of ``(B, s)`` encoded value planes ->
    ``(B, group_size * s)``.

    The value companion of the membership gather: algebras whose message is
    not the source id itself (sssp distances, cc labels, pagerank mass)
    assemble the column slice of *source values* next to the membership
    bits.  Values travel as raw int32 words (width-32 packing is the
    identity), priced like a :class:`repro.comm.formats.DenseFormat` of
    ``s`` words per rank per plane.
    """
    b, s = x.shape
    g = ex.all_gather(x, fmt="values").reshape(ex.group_size, b, s)
    return jnp.moveaxis(g, 0, 1).reshape(b, -1)


# ---------------------------------------------------------------------------
# row phase: candidate all-to-all + min-reduce
# ---------------------------------------------------------------------------


def alltoall_dense_min(ex: AdaptiveExchange, prop: jax.Array) -> jax.Array:
    """Dense int32 all-to-all + min (raw/bitmap row phase and the fallback)."""
    c, s = prop.shape
    fmt = DenseFormat(s)
    recv = ex.all_to_all(prop, fmt=fmt.name).reshape(c, s)
    return jnp.min(recv, axis=0)


def alltoall_min_candidates(
    prop: jax.Array,
    axis,
    ladder: BucketLadder,
    group_size: int,
    *,
    stats: CommStats | None = None,
    phase: str = "bfs/row",
    n_c: int | None = None,
):
    """Adaptive all-to-all + min-reduce of candidate parents (row phase).

    ``prop``: (group_size, s) int32 — proposal subchunk per destination rank
    (INF = no candidate).  Returns (s,) int32 min over all senders of the
    subchunk addressed to this rank.  Ids are delta+patched-packed; parent
    payloads are bit-packed at the ladder's stored ``payload_width`` class
    and ride in the same wire words as the ids.

    ``n_c`` (the column-slice width) localizes the payload: the sender's
    candidates are global ids ``j * n_c + src_l``, but ``payload_width`` only
    covers the column-local offset — packing the global value would silently
    truncate its high bits whenever ``bit_length(n-1)`` exceeds the class
    that covers ``n_c``.  The sender therefore strips its own ``j * n_c``
    base before packing and the receiver re-adds it per received row (the
    all-to-all row index IS the sender's column), which is lossless at any
    grid width.
    """
    s = ladder.s
    c = group_size
    ex = AdaptiveExchange(phase, axis, group_size, ladder, stats)
    if not ladder.specs:
        return ex.dispatch(None, [lambda _: alltoall_dense_min(ex, prop)])
    assert ladder.payload_width > 0, (
        "row-phase ladder must carry the parent payload: build it with "
        "BucketLadder.default(s, floor_words=s, payload_width=...)"
    )

    bits = prop < INF
    ids, counts = jax.vmap(lambda b: bp.compact_ids(b, s, fill=s))(bits)
    gaps = jax.vmap(bpref.gaps_from_sorted)(ids, counts)
    exc_counts = jnp.sum((gaps >> 16) > 0, axis=1)
    my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))
    base = 0 if n_c is None else jax.lax.axis_index(axis) * n_c

    def sparse_branch(fmt: IdStreamFormat):
        cap = fmt.spec.cap

        def run(_):
            def pack_one(ids_d, count_d, prop_d):
                par = prop_d[jnp.clip(ids_d[:cap], 0, s - 1)] - base
                return fmt.pack(ids_d, count_d, payload=par)

            words, meta = jax.vmap(pack_one)(ids, counts, prop)
            r_words = ex.all_to_all(words, fmt=fmt.name).reshape(c, fmt.data_words)
            r_meta = ex.all_to_all(meta, fmt=fmt.name, part="meta").reshape(c, 2)

            def unpack_one(w, m, sender):
                u_ids, u_count, par = fmt.unpack(w, m, fill=s)
                valid = jnp.arange(cap) < u_count
                seg = jnp.where(valid, u_ids[:cap], s)
                glob = par if n_c is None else par + sender * n_c
                val = jnp.where(valid, glob, INF)
                return seg, val

            segs, vals = jax.vmap(unpack_one)(
                r_words, r_meta, jnp.arange(c, dtype=jnp.int32)
            )
            red = jax.ops.segment_min(
                vals.reshape(-1), segs.reshape(-1), num_segments=s + 1
            )
            return red[:s].astype(jnp.int32)

        return run

    branches = [sparse_branch(f) for f in ladder.formats()] + [
        lambda _: alltoall_dense_min(ex, prop)
    ]
    return ex.dispatch(my_bucket, branches)


def alltoall_dense_min_planes(ex: AdaptiveExchange, prop: jax.Array) -> jax.Array:
    """Dense int32 all-to-all + min of ``(B, c, s)`` candidate planes."""
    b, c, s = prop.shape
    fmt = DenseFormat(s)
    recv = ex.all_to_all(
        jnp.moveaxis(prop, 0, 1), fmt=fmt.name
    ).reshape(c, b, s)
    return jnp.min(recv, axis=0)


def alltoall_dense_combine_planes(
    ex: AdaptiveExchange, prop: jax.Array, alg
) -> jax.Array:
    """Dense int32 all-to-all + algebra combine of ``(B, c, s)`` planes.

    The semiring-general row exchange: min-algebras reduce exactly like
    :func:`alltoall_dense_min_planes`; sum-algebras (pagerank) decode the
    received partial sums, add across senders and re-encode — the absent
    sentinel 0 decodes to the additive identity, so no masking is needed.
    """
    b, c, s = prop.shape
    fmt = DenseFormat(s)
    recv = ex.all_to_all(
        jnp.moveaxis(prop, 0, 1), fmt=fmt.name
    ).reshape(c, b, s)
    if alg.reduce == "min":
        return jnp.min(recv, axis=0)
    return alg.enc(jnp.sum(alg.dec(recv), axis=0))


def alltoall_min_candidates_planes(
    prop: jax.Array,
    axis,
    ladder: BucketLadder,
    group_size: int,
    *,
    stats: CommStats | None = None,
    phase: str = "bfs/row",
    n_c: int | None = None,
):
    """Adaptive all-to-all + min-reduce of ``(B, c, s)`` candidate planes.

    The batched analog of :func:`alltoall_min_candidates`: B source planes
    share one bucket consensus (max over every (destination, plane) stream)
    and one pair of wire collectives, with per-plane (count, exc) sidebands
    packed one word per plane.  Payload localization is per plane exactly as
    in the single-source exchange — candidates travel column-local and the
    receiver re-globalizes from the all-to-all row index.
    """
    b, c, s = prop.shape
    assert s == ladder.s and c == group_size, (prop.shape, ladder.s, group_size)
    ex = AdaptiveExchange(phase, axis, group_size, ladder, stats, planes=b)
    if not ladder.specs:
        return ex.dispatch(None, [lambda _: alltoall_dense_min_planes(ex, prop)])
    assert ladder.payload_width > 0, (
        "row-phase ladder must carry the parent payload: build it with "
        "BucketLadder.default(s, floor_words=s, payload_width=...)"
    )

    prop_t = jnp.moveaxis(prop, 0, 1)  # (c, B, s): all-to-all split layout
    bits = prop_t < INF
    flat = bits.reshape(c * b, s)
    ids, counts = jax.vmap(lambda x: bp.compact_ids(x, s, fill=s))(flat)
    gaps = jax.vmap(bpref.gaps_from_sorted)(ids, counts)
    exc_counts = jnp.sum((gaps >> 16) > 0, axis=1)
    my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))
    base = 0 if n_c is None else jax.lax.axis_index(axis) * n_c

    def sparse_branch(fmt: IdStreamFormat):
        cap = fmt.spec.cap

        def run(_):
            def pack_one(ids_d, count_d, prop_d):
                par = prop_d[jnp.clip(ids_d[:cap], 0, s - 1)] - base
                return fmt.pack(ids_d, count_d, payload=par)

            words, meta = jax.vmap(pack_one)(
                ids, counts, prop_t.reshape(c * b, s)
            )
            pmeta = pack_plane_meta(meta[:, 0], meta[:, 1]).reshape(c, b)
            r_words = ex.all_to_all(
                words.reshape(c, b, fmt.data_words), fmt=fmt.name
            ).reshape(c, b, fmt.data_words)
            r_meta = ex.all_to_all(pmeta, fmt=fmt.name, part="meta").reshape(c, b)

            def unpack_one(w, m, sender):
                cnt, exc = unpack_plane_meta(m)
                u_ids, u_count, par = fmt.unpack(
                    w, jnp.stack([cnt, exc]), fill=s
                )
                valid = jnp.arange(cap) < u_count
                seg = jnp.where(valid, u_ids[:cap], s)
                glob = par if n_c is None else par + sender * n_c
                val = jnp.where(valid, glob, INF)
                return seg, val

            senders = jnp.broadcast_to(
                jnp.arange(c, dtype=jnp.int32)[:, None], (c, b)
            )
            segs, vals = jax.vmap(jax.vmap(unpack_one))(r_words, r_meta, senders)

            def reduce_plane(seg_p, val_p):  # (c, cap) each
                red = jax.ops.segment_min(
                    val_p.reshape(-1), seg_p.reshape(-1), num_segments=s + 1
                )
                return red[:s].astype(jnp.int32)

            return jax.vmap(reduce_plane)(
                jnp.moveaxis(segs, 0, 1), jnp.moveaxis(vals, 0, 1)
            )

        return run

    branches = [sparse_branch(f) for f in ladder.formats()] + [
        lambda _: alltoall_dense_min_planes(ex, prop)
    ]
    return ex.dispatch(my_bucket, branches)


def alltoall_bitmap_min_planes(
    ex: AdaptiveExchange, prop: jax.Array, fmt: BitmapParentFormat,
    n_c: int | None,
) -> jax.Array:
    """Batched bottom-up row exchange: B found-bitmap + packed-parent planes
    per destination chunk, one all-to-all for all of them.  ``n_c=None``
    means the payload is already global (non-id algebras) — no per-sender
    re-globalization."""
    b, c, s = prop.shape
    assert s == fmt.s, (prop.shape, fmt.s)
    prop_t = jnp.moveaxis(prop, 0, 1)  # (c, B, s)
    words = jax.vmap(jax.vmap(fmt.pack))(prop_t)  # (c, B, data_words)
    recv = ex.all_to_all(words, fmt=fmt.name).reshape(c, b, fmt.data_words)
    bits, local = jax.vmap(jax.vmap(fmt.unpack))(recv)  # (c, B, s) each
    sender = jnp.arange(c, dtype=jnp.int32)[:, None, None]
    glob = local if n_c is None else sender * n_c + local
    glob = jnp.where(bits, glob, INF)
    return jnp.min(glob, axis=0).astype(jnp.int32)


def alltoall_bitmap_min(
    ex: AdaptiveExchange, prop: jax.Array, fmt: BitmapParentFormat,
    n_c: int | None,
) -> jax.Array:
    """Bottom-up row exchange: found-bitmap + bit-packed local parents.

    ``prop``: (group_size, s) int32 — *column-local* candidate parents per
    destination owner chunk (INF = no frontier neighbor found).  Each
    sender's subchunk travels as ``s/32`` found bits plus ``payload_width``
    bits per position; the receiver rebuilds global parent ids from the
    sender's grid-column index and min-reduces, reproducing exactly the
    winner the push direction's ``segment_min`` would pick.  ``n_c=None``
    disables the re-globalization for payloads that are already global
    values (non-id min-algebras, e.g. cc labels).
    """
    c, s = prop.shape
    assert s == fmt.s, (s, fmt.s)
    words = jax.vmap(fmt.pack)(prop)  # (c, data_words)
    recv = ex.all_to_all(words, fmt=fmt.name).reshape(c, fmt.data_words)
    bits, local = jax.vmap(fmt.unpack)(recv)  # (c, s) each
    sender = jnp.arange(c, dtype=jnp.int32)[:, None]  # grid-column of origin
    glob = local if n_c is None else sender * n_c + local
    glob = jnp.where(bits, glob, INF)
    return jnp.min(glob, axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# butterfly stages: adaptive merge-exchange of subchunk blocks (ppermute)
# ---------------------------------------------------------------------------


def ppermute_min_block(
    ex: AdaptiveExchange,
    block: jax.Array,
    perm,
    ladder: BucketLadder,
    floor_fmt,
    *,
    gate: jax.Array,
):
    """One butterfly stage: exchange a block of candidate subchunk planes.

    ``block``: (nb, b, s) int32 global candidate parents (INF = none) — the
    ``nb`` subchunks x ``b`` source planes this rank sends to its stage
    partner under ``perm``.  Returns the partner's (nb, b, s) block,
    reconstructed dense so the caller can min-merge it (ButterFly BFS: the
    merged stream is re-bucketed by the NEXT stage's call, so compression
    applies at every hop).

    The wire representation is chosen per stage by the ladder: sparse
    delta+PFOR16 id streams carrying the parent payload at the ladder's
    ``payload_width`` (which must cover GLOBAL ids — merged streams lose
    sender identity, so column-local offsets cannot ride a butterfly), with
    ``floor_fmt`` (found-bitmap + packed parents, or dense int32) as the
    dense floor.  With b > 1 planes the per-stream (count, exc) sidebands
    pack one word per plane (the shared header); the bucket consensus is a
    single round over every plane of every subchunk.  ``gate`` masks the
    consensus contribution of ranks that do not send at this stage (folded
    ranks), so their stale state never inflates the group's bucket choice.
    """
    nb, b, s = block.shape
    flat = block.reshape(nb * b, s)
    bits = flat < INF
    ids, counts = jax.vmap(lambda x: bp.compact_ids(x, s, fill=s))(bits)
    gaps = jax.vmap(bpref.gaps_from_sorted)(ids, counts)
    exc_counts = jnp.sum((gaps >> 16) > 0, axis=1)
    if ladder.specs:
        my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))
        my_bucket = jnp.where(gate, my_bucket, 0)
    else:
        my_bucket = None

    def sparse_branch(fmt: IdStreamFormat):
        cap = fmt.spec.cap

        def run(_):
            def pack_one(ids_d, count_d, block_d):
                par = block_d[jnp.clip(ids_d[:cap], 0, s - 1)]
                return fmt.pack(ids_d, count_d, payload=par)

            words, meta = jax.vmap(pack_one)(ids, counts, flat)
            if b > 1:
                meta = pack_plane_meta(meta[:, 0], meta[:, 1]).reshape(nb, b)
            words = words.reshape(nb, b, fmt.data_words)
            r_words = ex.ppermute(words, perm, fmt=fmt.name).reshape(
                nb * b, fmt.data_words
            )
            r_meta = ex.ppermute(meta, perm, fmt=fmt.name, part="meta")
            if b > 1:
                cnt, exc = unpack_plane_meta(r_meta.reshape(nb * b))
                r_meta = jnp.stack([cnt, exc], axis=1)

            def unpack_one(w, m):
                u_ids, u_count, par = fmt.unpack(w, m, fill=s)
                valid = jnp.arange(cap) < u_count
                seg = jnp.where(valid, u_ids[:cap], s)
                val = jnp.where(valid, par, INF)
                return jnp.full((s + 1,), INF, jnp.int32).at[seg].min(val)[:s]

            return jax.vmap(unpack_one)(r_words, r_meta).reshape(nb, b, s)

        return run

    def floor_branch(_):
        if isinstance(floor_fmt, BitmapParentFormat):
            words = jax.vmap(floor_fmt.pack)(flat).reshape(nb, b, -1)
            recv = ex.ppermute(words, perm, fmt=floor_fmt.name)
            f_bits, par = jax.vmap(floor_fmt.unpack)(recv.reshape(nb * b, -1))
            return jnp.where(f_bits, par, INF).reshape(nb, b, s)
        return ex.ppermute(block, perm, fmt=floor_fmt.name)

    branches = [sparse_branch(f) for f in ladder.formats()] + [floor_branch]
    return ex.dispatch(my_bucket, branches)


def ppermute_membership_block(
    ex: AdaptiveExchange,
    block: jax.Array,
    perm,
    ladder: BucketLadder,
    *,
    gate: jax.Array,
):
    """One butterfly all-gather stage: exchange a block of membership planes.

    ``block``: (nb, b, s) bool — the ``nb`` chunks x ``b`` source planes
    this rank forwards under ``perm``.  Returns the partner's (nb, b, s)
    bool block.  Sparse stages travel as delta+PFOR16 id streams per
    chunk-plane (with the one-word-per-plane packed sideband when b > 1),
    dense stages as width-1 bitmaps (the doubling block keeps chunk
    identity, so the merge is a plain concatenation/OR into the receiver's
    state).
    """
    nb, b, s = block.shape
    flat = block.reshape(nb * b, s)
    ids, counts = jax.vmap(lambda x: bp.compact_ids(x, s, fill=s))(flat)
    gaps = jax.vmap(bpref.gaps_from_sorted)(ids, counts)
    exc_counts = jnp.sum((gaps >> 16) > 0, axis=1)
    if ladder.specs:
        my_bucket = jnp.max(jax.vmap(ladder.bucket_for)(counts, exc_counts))
        my_bucket = jnp.where(gate, my_bucket, 0)
    else:
        my_bucket = None

    def sparse_branch(fmt: IdStreamFormat):
        cap = fmt.spec.cap

        def run(_):
            words, meta = jax.vmap(fmt.pack)(ids, counts)
            if b > 1:
                meta = pack_plane_meta(meta[:, 0], meta[:, 1]).reshape(nb, b)
            words = words.reshape(nb, b, fmt.data_words)
            r_words = ex.ppermute(words, perm, fmt=fmt.name).reshape(
                nb * b, fmt.data_words
            )
            r_meta = ex.ppermute(meta, perm, fmt=fmt.name, part="meta")
            if b > 1:
                cnt, exc = unpack_plane_meta(r_meta.reshape(nb * b))
                r_meta = jnp.stack([cnt, exc], axis=1)

            def unpack_one(w, m):
                u_ids, u_count, _ = fmt.unpack(w, m, fill=s)
                valid = jnp.arange(cap) < u_count
                seg = jnp.where(valid, u_ids[:cap], s)
                return jnp.zeros((s + 1,), bool).at[seg].set(True)[:s]

            return jax.vmap(unpack_one)(r_words, r_meta).reshape(nb, b, s)

        return run

    def bitmap_branch(_):
        fmt = BitmapFormat(s)
        words = jax.vmap(fmt.pack)(flat).reshape(nb, b, -1)
        recv = ex.ppermute(words, perm, fmt=fmt.name)
        return jax.vmap(fmt.unpack)(recv.reshape(nb * b, -1)).reshape(nb, b, s)

    branches = [sparse_branch(f) for f in ladder.formats()] + [bitmap_branch]
    return ex.dispatch(my_bucket, branches)


# ---------------------------------------------------------------------------
# beyond-paper: quantized all-reduce for data-parallel gradient sync
# ---------------------------------------------------------------------------


def allreduce_int8(
    x: jax.Array,
    axis,
    group_size: int,
    *,
    stats: CommStats | None = None,
    phase: str = "grad/allreduce",
) -> jax.Array:
    """Two-phase int8-quantized all-reduce (all_to_all scatter + all_gather).

    Phase 1 *scatters* quantized shard-chunks with a tiled ``all_to_all``
    (the static-shape stand-in for reduce_scatter: every rank receives the
    group's copies of its own chunk and sums them locally); phase 2
    re-quantizes the reduced chunk and ``all_gather``\\ s it.  Both wire
    transfers carry int8 payloads + f32 scales per 128 values — ~3.8x fewer
    bytes than an fp32 ring all-reduce.  Lossy; pair with error feedback
    (optim/grad_compress.py).  ``x`` length must divide by
    ``group_size * 128``.
    """
    n = x.shape[0]
    assert n % (group_size * quant.GROUP) == 0, n
    fmt = Int8Format(n)
    ex = AdaptiveExchange(phase, axis, group_size, ladder=None, stats=stats)
    # phase 1: quantize my shard-chunks, scatter-exchange, locally sum my chunk
    chunks = x.reshape(group_size, n // group_size)
    q, sc = jax.vmap(fmt.pack)(chunks)
    q_r = ex.all_to_all(q, fmt=fmt.name, part="q").reshape(group_size, -1)
    sc_r = ex.all_to_all(sc, fmt=fmt.name, part="scales").reshape(group_size, -1)
    partial = jnp.sum(jax.vmap(fmt.unpack)(q_r, sc_r), axis=0)
    # phase 2: quantize reduced chunk, all-gather
    q2, sc2 = fmt.pack(partial)
    q_all = ex.all_gather(q2, fmt=fmt.name, part="q")
    sc_all = ex.all_gather(sc2, fmt=fmt.name, part="scales")
    return fmt.unpack(q_all, sc_all).reshape(x.shape)
