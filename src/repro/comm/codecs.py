"""Host-side integer-sequence codecs (paper §5.2, Tables 5.4/5.5).

These are *variable-length* codecs operating on numpy arrays — the faithful
reproduction of the paper's compression study.  The paper's chosen codec is
Lemire's **S4-BP128 with delta coding on top** (Frame-of-Reference binary
packing, 128-integer blocks, per-block bit width); here the same scheme is
implemented (``BP128Delta``) next to the comparison codecs the paper tables
include: VByte/varint (Ueno et al.'s VLQ family), a dense bitmap codec
(Huiwei et al.'s bitmap-index family), patched FOR with exceptions
(NewPFOR-style), and raw copy.

Every codec implements ``encode(np.ndarray[uint32]) -> bytes`` and
``decode(bytes, n) -> np.ndarray[uint32]`` and is registered with the factory
in :mod:`repro.comm.registry` (the paper's §5.3 "Factory" pattern).
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

BLOCK = 128  # paper's S4-BP128 block length


def _required_bits(x: np.ndarray) -> int:
    """Bits needed to represent max(x) (0 -> 0 bits)."""
    if x.size == 0:
        return 0
    m = int(x.max())
    return int(m).bit_length()


def delta_encode(ids: np.ndarray) -> np.ndarray:
    """Sorted ids -> non-negative gaps (paper: delta compression / d-gaps)."""
    ids = np.asarray(ids, dtype=np.uint64)
    gaps = np.empty_like(ids)
    if ids.size:
        gaps[0] = ids[0]
        np.subtract(ids[1:], ids[:-1], out=gaps[1:])
    return gaps.astype(np.uint32)


def delta_decode(gaps: np.ndarray) -> np.ndarray:
    return np.cumsum(gaps.astype(np.uint64)).astype(np.uint32)


def zigzag_encode(x: np.ndarray) -> np.ndarray:
    """Signed -> unsigned interleave (used for non-monotone streams)."""
    x = x.astype(np.int64)
    return ((x << 1) ^ (x >> 63)).astype(np.uint32)


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64)
    return ((u >> 1) ^ (-(u & 1)).astype(np.uint64)).astype(np.int64)


# ---------------------------------------------------------------------------
# bit packing primitives (vertical layout shared with kernels/bitpack)
# ---------------------------------------------------------------------------


def pack_bits(values: np.ndarray, b: int) -> np.ndarray:
    """Pack ``values`` (< 2**b) into uint32 words, b bits each, LSB-first.

    Horizontal layout (classic): value i occupies bits [i*b, (i+1)*b) of the
    concatenated bit stream.  Used by the host codecs; the TPU kernel uses the
    vertical per-1024-chunk layout instead (see kernels/bitpack/ref.py).
    """
    if b == 0 or values.size == 0:
        return np.zeros(0, dtype=np.uint32)
    if b == 32:
        return values.astype(np.uint32)
    n = values.size
    nbits = n * b
    nwords = -(-nbits // 32)
    bit_idx = np.arange(n, dtype=np.uint64) * b
    word_idx = (bit_idx // 32).astype(np.int64)
    off = (bit_idx % 32).astype(np.uint64)
    v = values.astype(np.uint64)
    out = np.zeros(nwords + 1, dtype=np.uint64)
    np.bitwise_or.at(out, word_idx, (v << off) & 0xFFFFFFFF)
    spill = (v >> (np.uint64(32) - off)) * (off > 0)
    np.bitwise_or.at(out, word_idx + 1, spill)
    return out[:nwords].astype(np.uint32)


def unpack_bits(words: np.ndarray, b: int, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    if b == 0:
        return np.zeros(n, dtype=np.uint32)
    if b == 32:
        return words[:n].astype(np.uint32)
    w = np.concatenate([words.astype(np.uint64), np.zeros(1, dtype=np.uint64)])
    bit_idx = np.arange(n, dtype=np.uint64) * b
    word_idx = (bit_idx // 32).astype(np.int64)
    off = bit_idx % 32
    lo = w[word_idx] >> off
    hi = np.where(off > 0, w[word_idx + 1] << (np.uint64(32) - off), 0)
    mask = np.uint64((1 << b) - 1)
    return ((lo | hi) & mask).astype(np.uint32)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base codec interface (paper: "codec"/"scheme"/"encoding")."""

    name: str = "copy"
    is_sorted_input: bool = False  # True => codec applies delta first

    def encode(self, values: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        raise NotImplementedError

    def ratio(self, values: np.ndarray) -> float:
        """compression ratio = original / compressed (paper eq. (4))."""
        blob = self.encode(values)
        return (values.size * 4) / max(len(blob), 1)


class Copy(Codec):
    """No-op codec — the paper's "Copy (No C/D)" baseline row."""

    def __init__(self) -> None:
        super().__init__()
        object.__setattr__(self, "name", "copy")

    def encode(self, values: np.ndarray) -> bytes:
        return values.astype(np.uint32).tobytes()

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        return np.frombuffer(blob, dtype=np.uint32, count=n).copy()


class BP128(Codec):
    """Binary packing, 128-int blocks, per-block bit width (FOR family).

    The paper's S4-BP128 minus the SIMD lane interleave (layout differences
    do not change size).  Block header: 1 byte bit-width.  No exceptions —
    width is the block max (plain PackedBinary / AFOR-1).
    """

    def __init__(self, delta: bool = False, name: str | None = None) -> None:
        super().__init__()
        object.__setattr__(self, "name", name or ("bp128d" if delta else "bp128"))
        object.__setattr__(self, "is_sorted_input", delta)
        object.__setattr__(self, "_delta", delta)

    def encode(self, values: np.ndarray) -> bytes:
        values = np.asarray(values, dtype=np.uint32)
        if self._delta:
            values = delta_encode(values)
        out = bytearray()
        for s in range(0, values.size, BLOCK):
            blk = values[s : s + BLOCK]
            b = _required_bits(blk)
            out.append(b)
            out += pack_bits(blk, b).tobytes()
        return bytes(out)

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.uint32)
        pos = 0
        s = 0
        mv = memoryview(blob)
        while s < n:
            cnt = min(BLOCK, n - s)
            b = mv[pos]
            pos += 1
            nwords = -(-cnt * b // 32) if b else 0
            words = np.frombuffer(mv[pos : pos + 4 * nwords], dtype=np.uint32)
            pos += 4 * nwords
            out[s : s + cnt] = unpack_bits(words, b, cnt)
            s += cnt
        if self._delta:
            out = delta_decode(out)
        return out


class PFOR(Codec):
    """Patched Frame-of-Reference (NewPFOR-style exceptions, paper §5.2.B).

    Per block choose the width ``b`` minimizing packed size + exception cost;
    values >= 2**b store their high bits in an exception area (position byte +
    packed high bits), Zukowski-et-al's "patched coding".
    """

    def __init__(self, delta: bool = True) -> None:
        super().__init__()
        object.__setattr__(self, "name", "pfor-delta" if delta else "pfor")
        object.__setattr__(self, "is_sorted_input", delta)
        object.__setattr__(self, "_delta", delta)

    @staticmethod
    def _best_width(blk: np.ndarray) -> int:
        bits_full = _required_bits(blk)
        best_b, best_cost = bits_full, blk.size * bits_full
        for b in range(max(bits_full - 16, 0), bits_full):
            n_exc = int((blk >= (1 << b)).sum()) if b < 32 else 0
            if n_exc > blk.size // 8:  # bounded exception budget
                continue
            cost = blk.size * b + n_exc * (8 + max(bits_full - b, 0)) + 8
            if cost < best_cost:
                best_b, best_cost = b, cost
        return best_b

    def encode(self, values: np.ndarray) -> bytes:
        values = np.asarray(values, dtype=np.uint32)
        if self._delta:
            values = delta_encode(values)
        out = bytearray()
        for s in range(0, values.size, BLOCK):
            blk = values[s : s + BLOCK]
            bits_full = _required_bits(blk)
            b = self._best_width(blk)
            exc_pos = np.nonzero(blk >= (1 << b) if b < 32 else np.zeros_like(blk, bool))[0]
            low = blk & np.uint32((1 << b) - 1 if b < 32 else 0xFFFFFFFF)
            hb = max(bits_full - b, 0)
            out += struct.pack("<BBB", b, len(exc_pos), hb)
            out += pack_bits(low, b).tobytes()
            out += exc_pos.astype(np.uint8).tobytes()
            out += pack_bits((blk[exc_pos].astype(np.uint64) >> b).astype(np.uint32), hb).tobytes()
        return bytes(out)

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.uint32)
        pos, s = 0, 0
        mv = memoryview(blob)
        while s < n:
            cnt = min(BLOCK, n - s)
            b, n_exc, hb = struct.unpack_from("<BBB", mv, pos)
            pos += 3
            nwords = -(-cnt * b // 32) if b else 0
            low = unpack_bits(np.frombuffer(mv[pos : pos + 4 * nwords], np.uint32), b, cnt)
            pos += 4 * nwords
            exc_pos = np.frombuffer(mv[pos : pos + n_exc], np.uint8).astype(np.int64)
            pos += n_exc
            nwords_h = -(-n_exc * hb // 32) if hb else 0
            high = unpack_bits(np.frombuffer(mv[pos : pos + 4 * nwords_h], np.uint32), hb, n_exc)
            pos += 4 * nwords_h
            blk = low.astype(np.uint64)
            blk[exc_pos] |= high.astype(np.uint64) << b
            out[s : s + cnt] = blk.astype(np.uint32)
            s += cnt
        if self._delta:
            out = delta_decode(out)
        return out


class VByte(Codec):
    """Variable Byte / varint (paper §5.2.B.b — Ueno et al.'s VLQ family)."""

    def __init__(self, delta: bool = True) -> None:
        super().__init__()
        object.__setattr__(self, "name", "vbyte-delta" if delta else "vbyte")
        object.__setattr__(self, "is_sorted_input", delta)
        object.__setattr__(self, "_delta", delta)

    def encode(self, values: np.ndarray) -> bytes:
        values = np.asarray(values, dtype=np.uint32)
        if self._delta:
            values = delta_encode(values)
        v = values.astype(np.uint64)
        nbytes = np.maximum((64 - np.minimum(64, _nlz64(v))) + 6, 7) // 7
        out = np.zeros(int(nbytes.sum()), dtype=np.uint8)
        pos = np.concatenate([[0], np.cumsum(nbytes)[:-1]]).astype(np.int64)
        rem = v.copy()
        k = 0
        alive = np.ones(v.size, dtype=bool)
        while alive.any():
            idx = np.nonzero(alive)[0]
            byte = (rem[idx] & 0x7F).astype(np.uint8)
            more = (k + 1) < nbytes[idx]
            out[pos[idx] + k] = byte | (more.astype(np.uint8) << 7)
            rem[idx] >>= np.uint64(7)
            alive[idx] = more
            k += 1
        return out.tobytes()

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        data = np.frombuffer(blob, dtype=np.uint8)
        out = np.zeros(n, dtype=np.uint64)
        i = 0
        for j in range(n):
            shift, val = 0, 0
            while True:
                byte = int(data[i])
                i += 1
                val |= (byte & 0x7F) << shift
                if not byte & 0x80:
                    break
                shift += 7
            out[j] = val
        out32 = out.astype(np.uint32)
        return delta_decode(out32) if self._delta else out32


class Bitmap(Codec):
    """Dense bitmap of a sorted id set over a universe (Huiwei et al. family).

    Encodes *membership*, not order; only valid for strictly increasing
    unique ids.  Universe = max id + 1, stored as a header.
    """

    def __init__(self) -> None:
        super().__init__()
        object.__setattr__(self, "name", "bitmap")
        object.__setattr__(self, "is_sorted_input", True)

    def encode(self, values: np.ndarray) -> bytes:
        values = np.asarray(values, dtype=np.uint32)
        universe = int(values.max()) + 1 if values.size else 0
        words = np.zeros(-(-universe // 32) or 1, dtype=np.uint32)
        np.bitwise_or.at(words, values // 32, np.uint32(1) << (values % 32))
        return struct.pack("<I", universe) + words.tobytes()

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        (universe,) = struct.unpack_from("<I", blob, 0)
        words = np.frombuffer(blob, dtype=np.uint32, offset=4)
        bits = ((words[:, None] >> np.arange(32, dtype=np.uint32)) & 1).astype(bool).ravel()
        ids = np.nonzero(bits[:universe])[0].astype(np.uint32)
        assert ids.size == n, (ids.size, n)
        return ids


def _nlz64(v: np.ndarray) -> np.ndarray:
    """Number of leading zeros of uint64 (vectorized)."""
    v = v.astype(np.uint64)
    bits = np.zeros(v.shape, dtype=np.int64)
    x = v.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        hi = x >> np.uint64(shift)
        take = hi != 0
        bits[take] += shift
        x = np.where(take, hi, x)
    bits[v != 0] += 1  # bits = position of highest set bit
    return 64 - bits
