"""Compression-threshold policy (paper §5.4.3).

Compressing tiny messages costs more than it saves: the paper gates the
compression call on a minimum sequence length, and its Future Work (§9)
proposes *topology-aware* thresholds (skip compression between shared-memory
ranks where bandwidth is effectively infinite).  Both policies live here.

For the static-shape in-graph path the threshold is resolved at *trace time*
(message capacity is static), so the policy returns plain bools — no traced
control flow is needed.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """Decide whether a transfer of ``n_ints`` integers should be compressed.

    Attributes:
      min_ints: minimum element count before compression pays off
        (paper §5.4.3 — measured break-even on the Creek platform).
      same_host_bandwidth_gBps: modeled intra-host bandwidth (GB/s); transfers
        whose endpoints share a host skip compression when the modeled
        compress + transmit + decompress time exceeds plain transmit (§9).
      link_bandwidth_gBps: network link bandwidth, GB/s (TPU ICI ~50).
      codec_speed_mips: compression speed in millions of ints/second.  The
        paper's CPU S4-BP128 runs ~3200 MI/s; the on-device TPU bitpack
        kernel is VPU/memory-bound at ~50000 MI/s (819 GB/s / 16 B/int
        touched) — the default models the TPU kernel, since a CPU-speed
        codec cannot pay for itself against a 50 GB/s link.
      codec_dspeed_mips: decompression speed.
    """

    min_ints: int = 4096
    same_host_bandwidth_gBps: float = 200.0
    link_bandwidth_gBps: float = 50.0  # TPU ICI per-link, GB/s
    codec_speed_mips: float = 50_000.0
    codec_dspeed_mips: float = 50_000.0

    @classmethod
    def paper_creek(cls) -> "ThresholdPolicy":
        """The paper's environment: CPU SIMD codec + Gigabit Ethernet."""
        return cls(
            link_bandwidth_gBps=0.125,  # 1 Gbit/s
            codec_speed_mips=3200.0,  # Table 5.4, S4-BP128 on Creek
            codec_dspeed_mips=4700.0,
        )

    def _times(self, n_ints: int, ratio: float, same_host: bool):
        bw = (self.same_host_bandwidth_gBps if same_host else self.link_bandwidth_gBps) * 1e9
        plain_s = n_ints * 4 / bw
        comp_s = (
            n_ints / (self.codec_speed_mips * 1e6)
            + n_ints * 4 / (ratio * bw)
            + n_ints / (self.codec_dspeed_mips * 1e6)
        )
        return plain_s, comp_s

    def should_compress(self, n_ints: int, ratio: float, same_host: bool = False) -> bool:
        if n_ints < self.min_ints:
            return False
        plain_s, comp_s = self._times(n_ints, ratio, same_host)
        return comp_s < plain_s

    def modeled_speedup(self, n_ints: int, ratio: float, same_host: bool = False) -> float:
        """Transfer-time speedup of compressed vs plain under this model."""
        plain_s, comp_s = self._times(n_ints, ratio, same_host)
        return plain_s / comp_s

    def should_pack(
        self,
        n_values: int,
        packed_words: int,
        dense_words: int,
        stream_len: int | None = None,
        same_host: bool = False,
    ) -> bool:
        """Static-shape break-even for the in-graph packed wire formats.

        Unlike :meth:`should_compress` (host codec over a variable-length
        buffer), the in-graph codec touches exactly ``n_values`` bucket
        slots and ships ``packed_words`` u32 words against a dense fallback
        of ``dense_words`` words.  ``stream_len`` (the logical vector length
        ``s``) gates the paper's §5.4.3 minimum-size rule.  Consulted by
        :meth:`repro.comm.ladder.BucketLadder.default` when pruning buckets.
        """
        if stream_len is not None and stream_len < self.min_ints:
            return False
        bw = (self.same_host_bandwidth_gBps if same_host else self.link_bandwidth_gBps) * 1e9
        plain_s = dense_words * 4 / bw
        comp_s = (
            n_values / (self.codec_speed_mips * 1e6)
            + packed_words * 4 / bw
            + n_values / (self.codec_dspeed_mips * 1e6)
        )
        return comp_s < plain_s
