"""CommStats: byte accounting for every wire exchange.

Every collective issued through :class:`repro.comm.engine.AdaptiveExchange`
records one entry per HLO collective op it emits.  Byte counts follow the
same convention as :func:`repro.launch.roofline.parse_collectives` so the
two are directly comparable: **result-shape bytes per device**, with
all-reduce counted twice (the reduce + broadcast phases of a ring).

Two usage modes, not to be mixed on one instance:

* **trace recording** (:meth:`CommStats.record`): called while JAX traces a
  program.  Every entry's key ``(phase, fmt, collective, part)`` is fully
  static, so recording is a *set*, not an append — retracing the same
  program is idempotent, and each entry corresponds to exactly one
  collective op in the lowered HLO.
* **host replay accounting** (:meth:`CommStats.add`): benchmarks replaying
  a BFS level-by-level accumulate per-zone byte totals through the same
  object, so the byte arithmetic lives in one place (the wire formats)
  instead of being re-derived per benchmark.
"""

from __future__ import annotations

import dataclasses
import math

#: multiplier parse_collectives applies per HLO op kind (ring all-reduce
#: moves ~2x the operand: reduce phase + broadcast phase)
HLO_FACTOR = {"all-reduce": 2}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def aval_bytes(x) -> int:
    """Result-shape bytes of an array or tracer (bool counts as 1, = HLO pred)."""
    n = math.prod(x.shape) if x.shape else 1
    return int(n) * x.dtype.itemsize


@dataclasses.dataclass
class ExchangeRecord:
    phase: str  # logical exchange zone, e.g. "bfs/column"
    fmt: str  # wire-format name, e.g. "pfor16[1024]" / "bitmap" / "int8"
    collective: str  # HLO op kind (see COLLECTIVE_KINDS)
    part: str  # payload component: "words" | "meta" | "scales" | ...
    nbytes: int  # total result-shape bytes per device (all instances)
    count: int = 1  # op instances accumulated (informational)
    #: bytes that actually cross a link, per device (self-sends and the own
    #: chunk of a gather excluded; ring all-reduce counted at its true
    #: 2(g-1)/g volume).  Defaults to nbytes when the caller has no better
    #: model — HLO parity always uses nbytes, never this.
    moved_bytes: int = -1

    def __post_init__(self) -> None:
        if self.moved_bytes < 0:
            self.moved_bytes = self.nbytes

    @property
    def hlo_bytes(self) -> int:
        """Bytes as parse_collectives would count this entry."""
        return self.nbytes * HLO_FACTOR.get(self.collective, 1)


class CommStats:
    """Keyed exchange-byte ledger; see module docstring for conventions."""

    def __init__(self) -> None:
        self._records: dict[tuple[str, str, str, str], ExchangeRecord] = {}

    # -- trace-time recording (idempotent set) ------------------------------

    def record(self, phase: str, fmt: str, collective: str, part: str, nbytes: int,
               moved_bytes: int | None = None) -> None:
        assert collective in COLLECTIVE_KINDS, collective
        key = (phase, fmt, collective, part)
        rec = ExchangeRecord(phase, fmt, collective, part, int(nbytes),
                             moved_bytes=-1 if moved_bytes is None else int(moved_bytes))
        prev = self._records.get(key)
        if prev is not None and (
            (prev.nbytes, prev.count, prev.moved_bytes)
            != (rec.nbytes, rec.count, rec.moved_bytes)
        ):
            raise ValueError(
                f"CommStats key {key} re-recorded with different size "
                f"({prev.nbytes}x{prev.count} moved {prev.moved_bytes} -> "
                f"{rec.nbytes} moved {rec.moved_bytes})"
            )
        self._records[key] = rec

    def record_aval(self, phase: str, fmt: str, collective: str, part, x,
                    moved_bytes: int | None = None) -> None:
        """Record from a traced array's aval (shape/dtype known at trace time)."""
        self.record(phase, fmt, collective, part, aval_bytes(x),
                    moved_bytes=moved_bytes)

    # -- host-replay accumulation -------------------------------------------

    def add(self, phase: str, fmt: str, collective: str, nbytes: int,
            part: str = "words", count: int = 1) -> None:
        """Accumulate ``nbytes`` (already totaled) over ``count`` op instances."""
        assert collective in COLLECTIVE_KINDS, collective
        key = (phase, fmt, collective, part)
        rec = self._records.get(key)
        if rec is None:
            self._records[key] = ExchangeRecord(phase, fmt, collective, part,
                                                int(nbytes), count)
        else:
            # host-replay bytes are already true traffic: moved == nbytes
            rec.nbytes += int(nbytes)
            rec.moved_bytes += int(nbytes)
            rec.count += count

    # -- views ---------------------------------------------------------------

    def records(self) -> list[ExchangeRecord]:
        return [self._records[k] for k in sorted(self._records)]

    def per_phase(self) -> dict[str, int]:
        """phase -> bytes (HLO convention, all-reduce doubled)."""
        out: dict[str, int] = {}
        for r in self.records():
            out[r.phase] = out.get(r.phase, 0) + r.hlo_bytes
        return out

    def per_phase_moved(self) -> dict[str, int]:
        """phase -> true wire bytes (self-sends excluded; no HLO factor)."""
        out: dict[str, int] = {}
        for r in self.records():
            out[r.phase] = out.get(r.phase, 0) + r.moved_bytes
        return out

    def per_phase_fmt(self) -> dict[str, dict[str, int]]:
        """phase -> fmt -> bytes (host-replay benchmark tables)."""
        out: dict[str, dict[str, int]] = {}
        for r in self.records():
            out.setdefault(r.phase, {})
            out[r.phase][r.fmt] = out[r.phase].get(r.fmt, 0) + r.hlo_bytes
        return out

    def per_op(self) -> dict[str, int]:
        """op kind -> bytes; directly comparable to parse_collectives().per_op."""
        out: dict[str, int] = {}
        for r in self.records():
            out[r.collective] = out.get(r.collective, 0) + r.hlo_bytes
        return out

    @property
    def total_bytes(self) -> int:
        return sum(r.hlo_bytes for r in self.records())

    @property
    def total_moved_bytes(self) -> int:
        """True per-device wire traffic (identity permute pairs excluded)."""
        return sum(r.moved_bytes for r in self.records())

    def table(self) -> list[dict]:
        """JSON-friendly dump (BENCH_comm.json, dry-run artifacts)."""
        return [dataclasses.asdict(r) | {"hlo_bytes": r.hlo_bytes} for r in self.records()]
