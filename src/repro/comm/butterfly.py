"""Butterfly row exchange: log2(C)-stage merge-and-recompress wire plan.

ButterFly BFS (arXiv:2103.13577) replaces the row phase's direct ALLTOALLV —
whose per-rank cost grows with the grid width C — with a butterfly: each of
log2(C) stages exchanges with ONE partner (``ppermute``) and *re-compresses
the merged candidate stream* before the next hop, so the paper's adaptive
wire formats (PFOR16 id streams, bitmap + packed parents) are applied at
every stage instead of once.  Mapped onto the static-shape engine:

* **reduce-scatter butterfly** (push and pull row phases): the (C, s)
  candidate matrix is folded into a (P, slots, s) leaf state (P = largest
  power of two <= C); stage t pairs rank j with ``j ^ 2^t`` and moves the
  ``P / 2^(t+1)`` leaf rows whose destination bit t matches the partner,
  min-merging received rows into the kept half.  After all stages rank j
  holds exactly its own fully-reduced subchunk.
* **recursive-doubling butterfly** (the bottom-up unreached all-gather):
  the same pairing in the opposite direction — stage t forwards the
  2^t-chunk block accumulated so far, OR/concatenating the partner's block,
  until every rank holds the whole grid-row membership.
* **non-power-of-two C — folded first stage**: the ``extra = C - P``
  overhang ranks ppermute their entire candidate state onto ranks
  ``0..extra-1`` before stage 0 (each low rank's leaf gains a second slot
  for the overhang destination), idle through the power-of-two stages, and
  receive their reduced subchunk back in a final unfold ppermute.

Each stage records its bytes under its own CommStats zone
(``bfs/row[btfly:t]``, ``[btfly:fold]``, ``[btfly:unfold]``) so the ledger
reconciles 1:1 with the ``collective-permute`` ops in the lowered HLO, and
the host benchmark can replay the staged volumes against
:func:`stage_unit_bytes` — the static byte model of one subchunk on the
wire at each stage.

Because merged streams lose sender identity, the parent payload must carry
GLOBAL ids: :func:`row_wire` sizes the ladder's payload class from the full
vertex count (not the column-slice width the direct all-to-all localizes
to) and uses found-bitmap + packed-global-parent as the dense floor
whenever that class stays below 32 bits.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm import collectives as cc
from repro.comm.engine import AdaptiveExchange
from repro.comm.formats import (
    INF,
    BitmapFormat,
    BitmapParentFormat,
    DenseFormat,
    plane_wire_bytes,
)
from repro.comm.ladder import BucketLadder
from repro.kernels.bitpack.ref import B_CLASSES


def width_class(n: int) -> int:
    """Smallest bit-packing class covering ids in [0, n)."""
    need = max((n - 1).bit_length(), 1)
    for b in B_CLASSES:
        if b >= need:
            return b
    return 32


@dataclasses.dataclass(frozen=True)
class ButterflySchedule:
    """Static stage plan of the butterfly over ``c`` ranks.

    ``p`` is the largest power of two <= c; the ``extra = c - p`` overhang
    ranks fold onto ranks ``0..extra-1`` (their leaf gains a second slot)
    before the log2(p) pairwise stages, and unfold afterwards.
    """

    c: int

    @property
    def p(self) -> int:
        return 1 << (self.c.bit_length() - 1)

    @property
    def extra(self) -> int:
        return self.c - self.p

    @property
    def slots(self) -> int:
        return 2 if self.extra else 1

    @property
    def n_stages(self) -> int:
        return self.p.bit_length() - 1  # log2(p)

    def stage_perm(self, t: int) -> list[tuple[int, int]]:
        """Pairwise swap of stage ``t`` (overhang ranks idle)."""
        return [(r, r ^ (1 << t)) for r in range(self.p)]

    def stage_blocks(self, t: int) -> int:
        """Leaf rows exchanged at stage ``t`` (times ``slots`` subchunks)."""
        return self.p >> (t + 1)

    def fold_perm(self) -> list[tuple[int, int]]:
        return [(self.p + e, e) for e in range(self.extra)]

    def unfold_perm(self) -> list[tuple[int, int]]:
        return [(e, self.p + e) for e in range(self.extra)]

    def leaf_of_chunk(self, q: int) -> tuple[int, int]:
        """Grid-row chunk index -> (leaf row, slot)."""
        return (q, 0) if q < self.p else (q - self.p, 1)


def row_wire(
    s: int, n: int, policy=None, payload_width: int | None = None
) -> tuple[BucketLadder, BitmapParentFormat | DenseFormat]:
    """Ladder + dense floor of the butterfly row stages (shared with the
    host-replay benchmark so device and bench model the same wire).

    The payload class must cover GLOBAL parent ids in [0, n): a butterfly
    stage merges streams from several origin columns, so the receiver can
    no longer rebuild global ids from a sender index the way the direct
    exchanges do.  When that class stays below 32 bits the dense floor is
    the found-bitmap + packed-parent format (s/32 + s*w/32 words — the
    "bitmap OR-merge" of dense stages); at 32 bits it degenerates to the
    dense int32 vector.  ``payload_width`` overrides the id class for
    frontier algebras whose candidate payload is a value, not an id
    (already global either way — the width just prices/packs it).
    """
    w = width_class(n) if payload_width is None else payload_width
    floor: BitmapParentFormat | DenseFormat
    if w < 32:
        floor = BitmapParentFormat(s, w)
        floor_words = floor.data_words
    else:
        floor = DenseFormat(s)
        floor_words = s
    ladder = BucketLadder.default(
        s, floor_words=floor_words, payload_width=w, policy=policy
    )
    return ladder, floor


def unreached_wire(s: int, policy=None) -> tuple[BucketLadder, BitmapFormat]:
    """Ladder + bitmap floor of the staged unreached all-gather."""
    return BucketLadder.default(s, policy=policy), BitmapFormat(s)


def stage_unit_bytes(
    s: int, n: int, fmt_name: str, zone: str = "row", policy=None, b: int = 1
) -> int:
    """Static byte model: wire bytes of ONE subchunk (all ``b`` source
    planes) under ``fmt_name``.

    This is what the CI parity check recomputes against the staged volumes
    the host replay wrote into BENCH_comm.json — every stage's bytes must
    equal ``senders * subchunks * stage_unit_bytes(...)`` of the format the
    consensus picked there, up to packing padding.  ``zone`` selects the
    wire ("row" or "unreached"): the same ``pfor16[...]`` name prices
    differently on the two (the row stream carries the parent payload).
    With ``b > 1`` the id-stream sideband amortizes (one packed word per
    plane instead of two) while dense floors scale linearly — see
    :func:`repro.comm.formats.plane_wire_bytes`.
    """

    if zone == "row":
        ladder, floor = row_wire(s, n, policy=policy)
    elif zone == "unreached":
        ladder, floor = unreached_wire(s, policy=policy)
    else:
        raise KeyError(f"unknown butterfly zone {zone!r}")
    if fmt_name == floor.name:
        return plane_wire_bytes(floor, b)
    for fmt in ladder.formats():
        if fmt.name == fmt_name:
            return plane_wire_bytes(fmt, b)
    raise KeyError(f"unknown {zone} stage format {fmt_name!r}")


# ---------------------------------------------------------------------------
# reduce-scatter butterfly: the staged row phase (push and pull)
# ---------------------------------------------------------------------------


def build_row_exchange(
    s: int,
    axis,
    group_size: int,
    n_c: int,
    *,
    b: int = 1,
    to_global: bool = False,
    policy=None,
    stats=None,
    phase: str = "bfs/row",
    alg=None,
):
    """Build ``fn(prop (b, c, s) int32) -> (b, s) int32`` — the staged
    analog of the direct row ALLTOALLV + min, over ``b`` source planes.

    ``to_global`` globalizes column-local pull candidates (``j*n_c + local``)
    before the first stage; the push path's candidates are global already.
    Every stage moves all ``b`` planes of its subchunks in one ppermute pair
    and union-merges them per plane — the multi-source planes stack for
    free on the staged exchange's per-hop merge.

    ``alg`` generalizes the per-hop merge to a frontier algebra's combine
    (``None`` keeps the BFS min-parent semantics).  Min-algebras ride the
    same staged compressed wire (their payload width/globalization come
    from the algebra); the sum-algebra exchanges dense int32 value blocks
    per stage (a sum of partial sums is dense by construction — there is
    no sparse stream to re-bucket) and add-merges on the decoded values.
    """
    c = group_size
    n = n_c * c
    sched = ButterflySchedule(c)
    is_sum = alg is not None and alg.reduce == "sum"
    payload_is_id = alg is None or alg.payload_is_id
    ladder, floor = row_wire(
        s, n, policy=policy,
        payload_width=None if payload_is_id else alg.row_payload_width(n_c, n),
    )
    empty = jnp.int32(0 if is_sum else INF)
    combine = jnp.minimum if alg is None else alg.combine
    dense = DenseFormat(s)
    p, extra, slots = sched.p, sched.extra, sched.slots

    def exchange(block, perm, gate, zone):
        if is_sum:
            ex = AdaptiveExchange(zone, axis, c, None, stats, planes=b)
            return ex.ppermute(block, perm, fmt=dense.name)
        ex = AdaptiveExchange(zone, axis, c, ladder, stats, planes=b)
        return cc.ppermute_min_block(ex, block, perm, ladder, floor, gate=gate)

    def run(prop: jax.Array) -> jax.Array:
        assert prop.shape == (b, c, s), (prop.shape, b, c, s)
        j = jax.lax.axis_index(axis)
        if to_global and payload_is_id:
            prop = jnp.where(prop < INF, j * n_c + prop, INF)
        if c == 1:
            return prop[:, 0]
        jv = j & (p - 1)
        prop_t = jnp.moveaxis(prop, 0, 1)  # (c, b, s): leaf-major layout
        # leaf state: row k slot 0 = destination chunk k, slot 1 = chunk p+k
        main = prop_t[:p]
        if extra:
            over = jnp.concatenate(
                [prop_t[p:], jnp.full((p - extra, b, s), empty, jnp.int32)],
                axis=0,
            )
            state = jnp.stack([main, over], axis=1)  # (p, 2, b, s)
            # folded first stage: overhang ranks merge their whole candidate
            # state onto ranks 0..extra-1
            recv = exchange(
                state.reshape(p * slots, b, s),
                sched.fold_perm(),
                gate=j >= p,
                zone=f"{phase}[btfly:fold]",
            ).reshape(p, slots, b, s)
            state = combine(state, jnp.where(j < extra, recv, empty))
        else:
            state = main[:, None]  # (p, 1, b, s)

        for t in range(sched.n_stages):
            m = 1 << t
            nblk = sched.stage_blocks(t)
            send_base = (jv ^ m) & (2 * m - 1)
            keep_base = jv & (2 * m - 1)
            idx_send = send_base + 2 * m * jnp.arange(nblk, dtype=jnp.int32)
            idx_keep = keep_base + 2 * m * jnp.arange(nblk, dtype=jnp.int32)
            recv = exchange(
                state[idx_send].reshape(nblk * slots, b, s),
                sched.stage_perm(t),
                gate=j < p,
                zone=f"{phase}[btfly:{t}]",
            ).reshape(nblk, slots, b, s)
            if is_sum:
                state = state.at[idx_keep].set(combine(state[idx_keep], recv))
            else:
                state = state.at[idx_keep].min(recv)

        row = jnp.take(state, jv, axis=0)  # (slots, b, s) — my merged leaf
        own = row[0]  # (b, s)
        if extra:
            recv = exchange(
                row[1][None],
                sched.unfold_perm(),
                gate=j < extra,
                zone=f"{phase}[btfly:unfold]",
            )
            own = jnp.where(j >= p, recv[0], own)
        return own

    return run


# ---------------------------------------------------------------------------
# recursive-doubling butterfly: the staged unreached all-gather
# ---------------------------------------------------------------------------


def build_unreached_gather(
    s: int,
    axis,
    group_size: int,
    *,
    b: int = 1,
    policy=None,
    stats=None,
    phase: str = "bfs/unreached",
):
    """Build ``fn(bits (b, s) bool) -> (b, c*s) bool`` — staged membership
    all-gather over the grid row (bottom-up's unreached probe), one doubling
    schedule carrying all ``b`` source planes."""
    c = group_size
    sched = ButterflySchedule(c)
    ladder, _ = unreached_wire(s, policy=policy)
    p, extra, slots = sched.p, sched.extra, sched.slots

    def exchange(block, perm, gate, zone):
        ex = AdaptiveExchange(zone, axis, c, ladder, stats, planes=b)
        return cc.ppermute_membership_block(ex, block, perm, ladder, gate=gate)

    def run(bits: jax.Array) -> jax.Array:
        assert bits.shape == (b, s), (bits.shape, b, s)
        if c == 1:
            return bits
        j = jax.lax.axis_index(axis)
        jv = j & (p - 1)
        state = jnp.zeros((p, slots, b, s), bool)
        state = state.at[jv, 0].set(jnp.where(j < p, bits, False))
        if extra:
            recv = exchange(
                bits[None], sched.fold_perm(), gate=j >= p,
                zone=f"{phase}[btfly:fold]",
            )
            state = state.at[jv, 1].set(jnp.where(j < extra, recv[0], False))

        for t in range(sched.n_stages):
            blk = 1 << t
            start = (jv >> t) << t
            idx_mine = start + jnp.arange(blk, dtype=jnp.int32)
            idx_partner = (start ^ blk) + jnp.arange(blk, dtype=jnp.int32)
            recv = exchange(
                state[idx_mine].reshape(blk * slots, b, s),
                sched.stage_perm(t),
                gate=j < p,
                zone=f"{phase}[btfly:{t}]",
            ).reshape(blk, slots, b, s)
            state = state.at[idx_partner].set(jnp.where(j < p, recv, False))

        if extra:
            # overhang ranks need the whole gathered row slice back
            recv = exchange(
                state.reshape(p * slots, b, s),
                sched.unfold_perm(),
                gate=j < extra,
                zone=f"{phase}[btfly:unfold]",
            ).reshape(p, slots, b, s)
            state = jnp.where(j >= p, recv, state)
            flat = jnp.concatenate(
                [
                    jnp.moveaxis(state[:, 0], 0, 1).reshape(b, -1),
                    jnp.moveaxis(state[:extra, 1], 0, 1).reshape(b, -1),
                ],
                axis=1,
            )
        else:
            flat = jnp.moveaxis(state[:, 0], 0, 1).reshape(b, -1)
        return flat  # (b, c*s), chunk q of the row at [:, q*s:(q+1)*s]

    return run
