"""Bucket ladders: the capacity classes an adaptive exchange may carry.

Runtime variable sizing is replaced by a small ladder of precompiled
capacities.  Every rank computes the smallest bucket that fits its stream;
a ``pmax`` over the collective's axis makes the choice uniform inside each
communicator group; ``lax.switch`` dispatches to the branch whose
collective carries exactly that many words (see
:class:`repro.comm.engine.AdaptiveExchange`).

Bucket pruning is two-fold (the paper's §5.4.3 threshold, resolved at
trace time since all capacities are static):

* a bucket must genuinely undercut the dense floor in wire words, and
* it must win the modeled pack + transmit + unpack race against the dense
  fallback under :class:`repro.comm.threshold.ThresholdPolicy` —
  on a slow-codec/fast-link platform the ladder collapses to the dense
  representation exactly as the paper's break-even predicts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm.formats import IdStreamFormat, IdStreamSpec
from repro.comm.threshold import ThresholdPolicy
from repro.kernels.bitpack import ops as bp
from repro.kernels.bitpack import ref as bpref


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """Sparse-id buckets (ascending capacity) + dense fallback.

    ``s`` = chunk width (multiple of 1024).  ``floor_words`` is the dense
    fallback's wire size: s/32 for membership bitmaps (column phase), s for
    int32 candidate vectors (row phase) — the row phase therefore packs at
    far higher densities.  ``payload_width`` is stored on the ladder: it
    adds per-id payload words (packed parents) to each bucket's wire cost,
    both when pruning buckets and in :meth:`words_for_branch`.
    """

    s: int
    specs: tuple[IdStreamSpec, ...]
    floor_words: int
    payload_width: int = 0

    @classmethod
    def default(
        cls,
        s: int,
        floor_words: int | None = None,
        payload_width: int = 0,
        policy: ThresholdPolicy | None = None,
    ) -> "BucketLadder":
        policy = policy if policy is not None else ThresholdPolicy()
        floor = floor_words if floor_words is not None else s // 32
        caps: list[int] = []
        for frac in (256, 64, 16, 4):
            cap = max(s // frac, bpref.CHUNK)
            cap = min(cap, 1 << 16)
            wire = IdStreamSpec(cap).n_words + cap * payload_width // 32
            # keep buckets that undercut the dense floor AND beat it under
            # the modeled pack+transmit+unpack break-even
            if (
                cap < s
                and cap not in caps
                and wire < floor
                and policy.should_pack(cap, wire, floor, stream_len=s)
            ):
                caps.append(cap)
        return cls(
            s=s,
            specs=tuple(IdStreamSpec(c) for c in sorted(caps)),
            floor_words=floor,
            payload_width=payload_width,
        )

    @property
    def n_branches(self) -> int:
        return len(self.specs) + 1  # + dense fallback

    def bucket_for(self, count: jax.Array, exc_count: jax.Array) -> jax.Array:
        """Smallest usable bucket index for this rank (before pmax)."""
        b = jnp.int32(len(self.specs))  # dense fallback
        for i in range(len(self.specs) - 1, -1, -1):
            ok = (count <= self.specs[i].cap) & (exc_count <= self.specs[i].exc_cap)
            b = jnp.where(ok, jnp.int32(i), b)
        return b

    def words_for_branch(self, i: int) -> int:
        """Wire words of branch ``i`` (payload priced at the stored width)."""
        if i < len(self.specs):
            return self.specs[i].n_words + self.specs[i].cap * self.payload_width // 32
        return self.floor_words

    def formats(self) -> tuple[IdStreamFormat, ...]:
        """One sparse wire format per bucket (payload width baked in)."""
        return tuple(IdStreamFormat(spec, self.payload_width) for spec in self.specs)


def stream_stats(bits: jax.Array, s: int):
    """ids (s,), count, exception count of the gap stream (for bucketing)."""
    ids, count = bp.compact_ids(bits, s, fill=s)
    gaps = bpref.gaps_from_sorted(ids, count)
    exc_count = jnp.sum((gaps >> 16) > 0)
    return ids, count, exc_count
