"""The communication plane: one adaptive wire-format engine behind every
collective.

The paper's core contribution is choosing the cheapest wire representation
per exchange (compressed id stream vs dense bitmap, gated by a modeled
threshold).  This subsystem owns that choice end to end:

* :mod:`repro.comm.formats`  — the WireFormat geometry + pack/unpack
  (bitmap, PFOR16 id stream, raw ids, dense, int8-quantized).
* :mod:`repro.comm.ladder`   — bucket ladders pruned by word count AND the
  ThresholdPolicy break-even (paper §5.4.3).
* :mod:`repro.comm.engine`   — AdaptiveExchange: pmax group consensus +
  lax.switch dispatch + byte-accounted collective primitives.
* :mod:`repro.comm.stats`    — CommStats, the per-phase byte ledger whose
  entries correspond 1:1 with the collective ops in lowered HLO.
* :mod:`repro.comm.registry` — the unified wire-plan + host-codec factory
  (absorbs the old compression registry).
* :mod:`repro.comm.collectives` — the collective paths (BFS column, BFS
  row, butterfly stage exchanges, int8 gradient all-reduce) rebuilt on the
  engine.
* :mod:`repro.comm.butterfly` — the 'btfly' wire plan: log2(C)-stage
  merge-and-recompress row/unreached exchanges (ButterFly BFS).

Layering: core/distributed_bfs -> comm -> kernels (bitpack/quant).
The host-side variable-length codecs (:mod:`repro.comm.codecs`) and the
§5.4.3 break-even model (:mod:`repro.comm.threshold`) live here too; the
old ``repro.compression`` package is fully retired.
"""

from repro.comm.engine import AdaptiveExchange  # noqa: F401
from repro.comm.formats import (  # noqa: F401
    INF,
    BitmapFormat,
    BitmapParentFormat,
    DenseFormat,
    IdStreamFormat,
    IdStreamSpec,
    Int8Format,
    RawIdFormat,
    WireFormat,
    pack_bitmap,
    pack_id_stream,
    pack_plane_meta,
    plane_meta_words,
    plane_wire_bytes,
    unpack_bitmap,
    unpack_id_stream,
    unpack_plane_meta,
)
from repro.comm.ladder import BucketLadder, stream_stats  # noqa: F401
from repro.comm.stats import CommStats, ExchangeRecord  # noqa: F401
from repro.comm.collectives import (  # noqa: F401
    allgather_membership,
    allgather_membership_planes,
    allreduce_int8,
    alltoall_bitmap_min,
    alltoall_bitmap_min_planes,
    alltoall_min_candidates,
    alltoall_min_candidates_planes,
)
from repro.comm import butterfly  # noqa: F401
from repro.comm import registry  # noqa: F401
from repro.comm.threshold import ThresholdPolicy  # noqa: F401
