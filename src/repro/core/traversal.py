"""Traversal policies: direction optimization as a first-class layer.

Beamer's direction-optimizing BFS (paper §3.1; Beamer et al. SC'12)
switches between push (top-down) and pull (bottom-up) expansion per level.
In the vectorized TPU formulation both directions touch every edge, so
what survives of the *work* saving is the *representation* switch the
paper builds its compressed exchanges on: sparse levels want packed id
streams and push expansion, dense levels want bitmap wires and pull
expansion.  This module makes that choice a pluggable policy, resolved by
name through :func:`repro.comm.registry.traversal`:

* ``top_down``      — push: frontier sources propose parents
  (``segment_min`` over the edge list); the distributed row phase
  exchanges candidate id streams (the ALLTOALLV analog).
* ``bottom_up``     — pull: only unreached destinations accumulate
  candidates, the frontier is probed through its *packed bitmap* (the same
  vertical width-1 gather the Pallas SpMV kernels use; the ELL hot-spot
  version is :mod:`repro.kernels.spmv.pull`), and the distributed row
  phase swaps the id-stream ALLTOALLV for an unreached-bitmap all-gather
  plus a found-bitmap + bit-packed-parent exchange
  (:class:`repro.comm.BitmapParentFormat`).
* ``direction_opt`` — Beamer-style per-level switch driven by the
  popcount :class:`DensityOracle`, with the switch state threaded through
  the level-loop carry.

The density signal — the frontier popcount against the alpha/beta
thresholds — is the same per-chunk stream count the
:class:`repro.comm.ladder.BucketLadder` buckets on, and the default alpha
is derived from the ladder's largest sparse capacity
(:func:`ladder_alpha`): the traversal flips to pull exactly where the wire
would fall off the id-stream ladder onto its dense floor.  Policy choice
and wire choice therefore come from one oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import registry as wire_registry
from repro.comm.formats import INF, pack_bitmap
from repro.comm.ladder import BucketLadder
from repro.kernels.popcount import ops as pc_ops
from repro.kernels.spmv import ref as spmv_ref


def _pad_to_chunk(bits: jax.Array) -> jax.Array:
    """Zero-pad a membership vector to the 1024-bit packing chunk."""
    pad = (-bits.shape[0]) % 1024
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((pad,), bits.dtype)])
    return bits


def ladder_alpha(
    s: int, payload_width: int, threshold=None, default: float = 0.25
) -> float:
    """Bottom-up entry density derived from the row ladder's geometry.

    The pull representation wins exactly when the per-chunk candidate count
    overflows the ladder's largest sparse bucket (the wire would fall to
    the dense floor); below that, packed id streams are cheaper than the
    density-independent bitmap+parent exchange.  ``threshold`` must be the
    same ThresholdPolicy the wire plan's row ladder is built with, so a
    pruned ladder moves the direction switch too.
    """
    ladder = BucketLadder.default(
        s, floor_words=s, payload_width=payload_width, policy=threshold
    )
    return ladder.specs[-1].cap / s if ladder.specs else default


@dataclasses.dataclass(frozen=True)
class DensityOracle:
    """Popcount-based frontier-density oracle (direction AND wire signal).

    ``local_count`` is the membership popcount over the packed bitmap —
    computed by the :mod:`repro.kernels.popcount` kernel, and the exact
    quantity the BucketLadder thresholds on for the wire representation.
    ``next_direction`` applies alpha/beta hysteresis on the same count.
    """

    n: int  # vertex count the density is measured against
    alpha: float = 0.25  # switch to bottom-up above this frontier density
    beta: float = 0.05  # fall back to top-down below this density

    def local_count(self, bits: jax.Array) -> jax.Array:
        """Frontier size via the popcount kernel over the packed bitmap."""
        words = pack_bitmap(_pad_to_chunk(bits))
        return jnp.sum(pc_ops.popcount_blocks(words)).astype(jnp.int32)

    def next_direction(self, count, was_bottom_up):
        """Hysteresis: enter pull above alpha*n, leave below beta*n."""
        c = jnp.asarray(count, jnp.float32)
        return jnp.where(
            jnp.asarray(was_bottom_up, bool),
            c >= self.beta * self.n,
            c > self.alpha * self.n,
        )


class DistLevelCtx(NamedTuple):
    """Everything a policy needs to expand one distributed BFS level.

    Built once per rank by :func:`repro.core.distributed_bfs._bfs_local`;
    the exchange callables come from the wire plan
    (:class:`repro.comm.registry.WirePlan`), so a policy never touches a
    collective primitive directly.
    """

    src_l: jax.Array  # (e_cap,) column-local sources, n_c = padding
    dst_l: jax.Array  # (e_cap,) row-local destinations, n_r = padding
    n_r: int  # row-slice width (destinations per grid row)
    n_c: int  # column-slice width (sources per grid column)
    s: int  # owned-chunk width
    c: int  # grid columns
    col_index: jax.Array  # this rank's grid-column index j
    row_exchange: Callable | None  # push: (c,s) global candidates -> (s,) min
    row_exchange_bu: Callable | None  # pull: (c,s) LOCAL candidates -> (s,) min
    unreached_gather: Callable | None  # (s,) own unreached -> (n_r,) row slice


class TraversalPolicy:
    """One frontier-expansion direction, or a per-level switch over them.

    ``propose_single`` produces the (n,) candidate-parent vector for the
    single-device driver; ``expand_dist`` runs local expansion + the row
    exchange inside ``shard_map`` and returns the (s,) min-reduced global
    candidates for the owned chunk.  All policies produce *identical*
    parent/level results — they differ in probe representation and wire
    shape only.
    """

    name: str = ""
    starts_bottom_up: bool = False
    uses_top_down: bool = True  # driver builds the push row exchange
    uses_bottom_up: bool = False  # driver builds the pull exchanges

    def propose_single(self, src, dst, n, parent, frontier, use_bu):
        raise NotImplementedError

    def expand_dist(self, ctx: DistLevelCtx, parent, f_col, use_bu):
        raise NotImplementedError

    def next_direction(self, oracle: DensityOracle, count, use_bu):
        """Direction for the next level (fixed for single-direction policies)."""
        return jnp.bool_(self.starts_bottom_up)


class TopDownPolicy(TraversalPolicy):
    name = "top_down"

    def propose_single(self, src, dst, n, parent, frontier, use_bu):
        # push: every frontier source proposes itself to its neighbors
        cand = jnp.where(frontier[jnp.minimum(src, n - 1)] & (src < n), src, INF)
        return jax.ops.segment_min(cand, dst, num_segments=n + 1)[:n]

    def expand_dist(self, ctx, parent, f_col, use_bu):
        active = f_col[jnp.clip(ctx.src_l, 0, ctx.n_c - 1)] & (ctx.src_l < ctx.n_c)
        cand = jnp.where(active, ctx.col_index * ctx.n_c + ctx.src_l, INF)
        prop = jax.ops.segment_min(cand, ctx.dst_l, num_segments=ctx.n_r + 1)
        return ctx.row_exchange(prop[: ctx.n_r].reshape(ctx.c, ctx.s))


class BottomUpPolicy(TraversalPolicy):
    name = "bottom_up"
    starts_bottom_up = True
    uses_top_down = False
    uses_bottom_up = True

    def propose_single(self, src, dst, n, parent, frontier, use_bu):
        # pull: probe the *packed* frontier bitmap (the representation
        # switch; same vertical width-1 gather as kernels/spmv), and only
        # unreached destinations accumulate candidates
        n_pad = n + (-n) % 1024
        words = pack_bitmap(_pad_to_chunk(frontier))
        hit = spmv_ref.frontier_bit(words, src, n_pad) & (src < n)
        unreached = parent < 0
        pull = unreached[jnp.minimum(dst, n - 1)] & (dst < n)
        cand = jnp.where(hit & pull, src, INF)
        return jax.ops.segment_min(cand, dst, num_segments=n + 1)[:n]

    def expand_dist(self, ctx, parent, f_col, use_bu):
        # unreached membership of the whole row slice, gathered as bitmaps
        # over the grid row — this replaces the id-stream ALLTOALLV sizing
        unreached = ctx.unreached_gather(parent < 0)  # (n_r,) bool
        active = (
            f_col[jnp.clip(ctx.src_l, 0, ctx.n_c - 1)]
            & (ctx.src_l < ctx.n_c)
            & unreached[jnp.clip(ctx.dst_l, 0, ctx.n_r - 1)]
            & (ctx.dst_l < ctx.n_r)
        )
        # candidates stay column-LOCAL so the wire payload bit-packs at the
        # static column-width class; the receiver globalizes per sender
        cand = jnp.where(active, ctx.src_l, INF)
        prop = jax.ops.segment_min(cand, ctx.dst_l, num_segments=ctx.n_r + 1)
        return ctx.row_exchange_bu(prop[: ctx.n_r].reshape(ctx.c, ctx.s))


class DirectionOptPolicy(TraversalPolicy):
    """Beamer-style per-level switch between push and pull.

    The direction flag lives in the level-loop carry; both branches are in
    the traced program (``lax.cond``) and the flag is group-uniform because
    it derives from the globally ``psum``-ed frontier count — the same
    consensus shape the AdaptiveExchange uses for bucket dispatch.
    """

    name = "direction_opt"
    uses_top_down = True
    uses_bottom_up = True

    def __init__(self):
        self._td = TopDownPolicy()
        self._bu = BottomUpPolicy()

    def propose_single(self, src, dst, n, parent, frontier, use_bu):
        return jax.lax.cond(
            use_bu,
            lambda _: self._bu.propose_single(src, dst, n, parent, frontier, use_bu),
            lambda _: self._td.propose_single(src, dst, n, parent, frontier, use_bu),
            operand=None,
        )

    def expand_dist(self, ctx, parent, f_col, use_bu):
        return jax.lax.cond(
            use_bu,
            lambda _: self._bu.expand_dist(ctx, parent, f_col, use_bu),
            lambda _: self._td.expand_dist(ctx, parent, f_col, use_bu),
            operand=None,
        )

    def next_direction(self, oracle, count, use_bu):
        return oracle.next_direction(count, use_bu)


def level_once(src, dst, n, policy: TraversalPolicy, oracle: DensityOracle, state):
    """One single-device BFS level: policy proposal + state update.

    The single shared implementation behind both ``bfs()`` and
    ``bfs_levels()`` — ``state`` is any NamedTuple with parent / level /
    frontier / depth / active / use_bu fields.
    """
    proposed = policy.propose_single(
        src, dst, n, state.parent, state.frontier, state.use_bu
    )
    new = (proposed < INF) & (state.parent < 0)
    count = oracle.local_count(new)
    return state._replace(
        parent=jnp.where(new, proposed, state.parent),
        level=jnp.where(new, state.depth + 1, state.level),
        frontier=new,
        depth=state.depth + 1,
        active=count > 0,
        use_bu=policy.next_direction(oracle, count, state.use_bu),
    )


def resolve(name: str) -> TraversalPolicy:
    """Resolve a policy by name through the unified registry."""
    return wire_registry.traversal(name)


POLICIES = ("top_down", "bottom_up", "direction_opt")

for _p in (TopDownPolicy(), BottomUpPolicy(), DirectionOptPolicy()):
    wire_registry.register_traversal(_p)
del _p
