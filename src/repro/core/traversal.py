"""Traversal policies: direction optimization as a first-class layer.

Beamer's direction-optimizing BFS (paper §3.1; Beamer et al. SC'12)
switches between push (top-down) and pull (bottom-up) expansion per level.
In the vectorized TPU formulation both directions touch every edge, so
what survives of the *work* saving is the *representation* switch the
paper builds its compressed exchanges on: sparse levels want packed id
streams and push expansion, dense levels want bitmap wires and pull
expansion.  This module makes that choice a pluggable policy, resolved by
name through :func:`repro.comm.registry.traversal`:

* ``top_down``      — push: frontier sources propose parents
  (``segment_min`` over the edge list); the distributed row phase
  exchanges candidate id streams (the ALLTOALLV analog).
* ``bottom_up``     — pull: only unreached destinations accumulate
  candidates, the frontier is probed through its *packed bitmap* (the same
  vertical width-1 gather the Pallas SpMV kernels use; the ELL hot-spot
  version is :mod:`repro.kernels.spmv.pull`), and the distributed row
  phase swaps the id-stream ALLTOALLV for an unreached-bitmap all-gather
  plus a found-bitmap + bit-packed-parent exchange
  (:class:`repro.comm.BitmapParentFormat`).
* ``direction_opt`` — Beamer-style per-level switch driven by the
  popcount :class:`DensityOracle`, with the switch state threaded through
  the level-loop carry.

The density signal — the frontier popcount against the alpha/beta
thresholds — is the same per-chunk stream count the
:class:`repro.comm.ladder.BucketLadder` buckets on, and the default alpha
is derived from the ladder's largest sparse capacity
(:func:`ladder_alpha`): the traversal flips to pull exactly where the wire
would fall off the id-stream ladder onto its dense floor.  Policy choice
and wire choice therefore come from one oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import registry as wire_registry
from repro.comm.formats import INF, pack_bitmap
from repro.comm.ladder import BucketLadder
from repro.core import expand as expand_mod
from repro.kernels.bitpack import ops as bp_ops
from repro.kernels.popcount import ops as pc_ops


def _pad_to_chunk(bits: jax.Array) -> jax.Array:
    """Zero-pad a membership vector to the 1024-bit packing chunk."""
    pad = (-bits.shape[0]) % 1024
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((pad,), bits.dtype)])
    return bits


def ladder_alpha(
    s: int, payload_width: int, threshold=None, default: float = 0.25
) -> float:
    """Bottom-up entry density derived from the row ladder's geometry.

    The pull representation wins exactly when the per-chunk candidate count
    overflows the ladder's largest sparse bucket (the wire would fall to
    the dense floor); below that, packed id streams are cheaper than the
    density-independent bitmap+parent exchange.  ``threshold`` must be the
    same ThresholdPolicy the wire plan's row ladder is built with, so a
    pruned ladder moves the direction switch too.
    """
    ladder = BucketLadder.default(
        s, floor_words=s, payload_width=payload_width, policy=threshold
    )
    return ladder.specs[-1].cap / s if ladder.specs else default


@dataclasses.dataclass(frozen=True)
class DensityOracle:
    """Popcount-based frontier-density oracle (direction AND wire signal).

    ``local_count`` is the membership popcount over the packed bitmap —
    computed by the :mod:`repro.kernels.popcount` kernel, and the exact
    quantity the BucketLadder thresholds on for the wire representation;
    ``plane_counts`` is its multi-source form (one kernel call over all B
    frontier planes).  ``next_direction`` applies alpha/beta hysteresis on
    the same count, per plane, and accepts the *anticipatory* Beamer signal:
    ``m_f`` (edges incident to the frontier) against ``m_u`` (edges incident
    to still-unreached vertices).  A hub entering the frontier blows up
    ``m_f`` one level before the vertex count crosses ``alpha * n``, so the
    edge rule ``alpha_mf * m_f > m_u`` catches the dense level one step
    earlier than the popcount alone (Beamer et al. SC'12, alpha = 14).
    """

    n: int  # vertex count the density is measured against
    alpha: float = 0.25  # switch to bottom-up above this frontier density
    beta: float = 0.05  # fall back to top-down below this density
    alpha_mf: float = 14.0  # Beamer edge heuristic: enter pull when
    #                         alpha_mf * m_f > m_u (m_f from the degree dot)

    def local_count(self, bits: jax.Array) -> jax.Array:
        """Frontier size via the popcount kernel over the packed bitmap."""
        words = pack_bitmap(_pad_to_chunk(bits))
        return jnp.sum(pc_ops.popcount_blocks(words)).astype(jnp.int32)

    def plane_counts(self, bits: jax.Array) -> jax.Array:
        """Per-plane frontier sizes of ``(B, n)`` membership planes.

        One plane-blocked popcount kernel call covers every source: the
        planes pack through :func:`repro.kernels.bitpack.ops.pack_planes`
        (chunk-aligned flattening) and reduce through ``popcount_planes``.
        """
        b, n = bits.shape
        pad = (-n) % 1024
        if pad:
            bits = jnp.concatenate(
                [bits, jnp.zeros((b, pad), bits.dtype)], axis=1
            )
        words = bp_ops.pack_planes(bits.astype(jnp.uint32), 1)
        return pc_ops.popcount_planes(words)

    def next_direction(self, count, was_bottom_up, m_f=None, m_u=None,
                       growing=None):
        """Hysteresis: enter pull above alpha*n (or on the Beamer edge
        signal when ``m_f``/``m_u`` are provided), leave below beta*n.
        Elementwise over per-source planes.

        The edge rule carries Beamer's growing-frontier guard (``growing``:
        this level's frontier outgrew the last one, SC'12's C_TB condition):
        without it, ``m_u`` collapsing toward zero on the sparse tail of a
        deep traversal makes ``alpha_mf * m_f > m_u`` true on every level
        and the direction flaps into the density-independent pull wire
        where tiny packed id streams would do.
        """
        c = jnp.asarray(count, jnp.float32)
        enter = c > self.alpha * self.n
        if m_f is not None:
            edge = (
                self.alpha_mf * jnp.asarray(m_f, jnp.float32)
                > jnp.asarray(m_u, jnp.float32)
            )
            if growing is not None:
                edge = edge & jnp.asarray(growing, bool)
            enter = enter | edge
        return jnp.where(
            jnp.asarray(was_bottom_up, bool),
            c >= self.beta * self.n,
            enter,
        )


def degree_vector(src, dst, n_src: int, n_dst: int) -> jax.Array:
    """Per-destination degree of one edge block (padding excluded).

    The single masked segment-sum convention behind the anticipatory
    oracle on BOTH drivers: ``bfs`` feeds the full symmetric edge list
    (``n_src == n_dst == n``), the distributed driver its column-local
    block (``n_c``/``n_r`` bounds, followed by a grid-row psum) — one
    definition, so the two m_f signals cannot drift.
    """
    valid = (src < n_src) & (dst < n_dst)
    return jax.ops.segment_sum(
        valid.astype(jnp.int32), jnp.minimum(dst, n_dst), num_segments=n_dst + 1
    )[:n_dst]


def edge_signals(deg, new, parent):
    """Beamer ``(m_f, m_u)`` degree dots over ``(B, n)`` planes.

    ``m_f``: edges incident to the new frontier; ``m_u``: edges incident to
    what remains unreached after this level.  float32 — the dots reach 2m,
    which wraps int32 at Graph500 scales, and the oracle only thresholds
    the ratio.  Shared by both drivers so their direction decisions agree.
    """
    degf = deg.astype(jnp.float32)[None, :]
    m_f = jnp.sum(jnp.where(new, degf, 0.0), axis=1)
    m_u = jnp.sum(jnp.where((parent < 0) & ~new, degf, 0.0), axis=1)
    return m_f, m_u


class DistLevelCtx(NamedTuple):
    """Everything a policy needs to expand one distributed BFS level.

    Built once per rank by :func:`repro.core.distributed_bfs._bfs_local`;
    the exchange callables come from the wire plan
    (:class:`repro.comm.registry.WirePlan`) and the local expansion from
    the expansion backend (:func:`repro.comm.registry.expansion`), so a
    policy never touches a collective primitive or a block data structure
    directly.  All exchange callables are plane-batched: they carry every
    source plane of the batch in one collective.
    """

    expand: object  # ExpansionBackend: push_planes / pull_planes
    block: object  # its LocalBlock (COO edges / ELL slab / hybrid split)
    n_r: int  # row-slice width (destinations per grid row)
    n_c: int  # column-slice width (sources per grid column)
    s: int  # owned-chunk width
    c: int  # grid columns
    col_index: jax.Array  # this rank's grid-column index j
    row_exchange: Callable | None  # push: (B,c,s) global candidates -> (B,s) min
    row_exchange_bu: Callable | None  # pull: (B,c,s) LOCAL candidates -> (B,s)
    unreached_gather: Callable | None  # (B,s) own unreached -> (B,n_r) row slice
    algebra: object = None  # FrontierAlgebra (None = historical min-parent BFS)
    row_base: jax.Array | int = 0  # global id of this rank's first row (i*n_r)


class TraversalPolicy:
    """One frontier-expansion direction, or a per-level switch over them.

    ``propose_batch`` produces the (B, n) candidate-parent planes for the
    single-device driver (direction_opt runs one gated pass per direction
    so no branch runs that no plane is in); ``expand_dist`` runs local
    expansion + the row exchange inside ``shard_map`` over ALL planes at
    once — ``parent``/``f_col`` carry a leading (B,) plane axis,
    ``use_bu``/``active`` are per-plane flags, and the result is the (B, s)
    min-reduced global candidates for the owned chunk.  Both dispatch the
    *local* expansion through an expansion backend
    (:mod:`repro.core.expand`): the policy owns direction, probe masking,
    and the wire shape; the backend owns the block data structure.  All
    (policy x backend) combinations produce *identical* parent/level
    results — they differ in probe representation and wire shape only.
    """

    name: str = ""
    starts_bottom_up: bool = False
    uses_top_down: bool = True  # driver builds the push row exchange
    uses_bottom_up: bool = False  # driver builds the pull exchanges

    def propose_batch(self, expand, block, value, frontier, use_bu,
                      alg=None, x=None, plane_mask=None):
        """(B, n) candidate planes for the single-device driver.

        ``value`` is the algebra's state plane (the parent vector for BFS);
        ``alg``/``x`` switch value algebras onto the backend's value
        expansion (``x`` = the per-source message operands); ``plane_mask``
        restricts the pull mask to the planes a gated pass serves.
        """
        raise NotImplementedError

    def expand_dist(self, ctx: DistLevelCtx, value, f_col, use_bu, active,
                    x_col=None, plane_mask=None):
        raise NotImplementedError

    def next_direction(self, oracle: DensityOracle, count, use_bu,
                       m_f=None, m_u=None, growing=None):
        """Direction for the next level (fixed for single-direction
        policies); elementwise over the per-source count planes."""
        return jnp.broadcast_to(
            jnp.bool_(self.starts_bottom_up), jnp.shape(count)
        )


class TopDownPolicy(TraversalPolicy):
    name = "top_down"

    def propose_batch(self, expand, block, value, frontier, use_bu,
                      alg=None, x=None, plane_mask=None):
        # push: every frontier source proposes itself (or its value's edge
        # message) to its neighbors
        if alg is None or alg.payload_is_id:
            return expand.push_planes(block, frontier)
        return expand.push_value_planes(block, frontier, x, alg)

    def _propose(self, ctx, f_col, x_col):
        """(B, n_c) frontier planes -> (B, c, s) global candidate planes.

        Id payloads: the backend returns column-LOCAL min candidates; the
        push wire carries global ids, and min commutes with the constant
        shift ``j * n_c``, so globalizing after the min is exact.  Value
        payloads are already global — the backend's value expansion takes
        the id bases only to derive edge messages."""
        alg = ctx.algebra
        if alg is None or alg.payload_is_id:
            local = ctx.expand.push_planes(ctx.block, f_col)  # (B, n_r)
            glob = jnp.where(local < INF, ctx.col_index * ctx.n_c + local, INF)
        else:
            glob = ctx.expand.push_value_planes(
                ctx.block, f_col, x_col, alg,
                row_base=ctx.row_base, col_base=ctx.col_index * ctx.n_c,
            )
        return glob.reshape(-1, ctx.c, ctx.s)

    def expand_dist(self, ctx, value, f_col, use_bu, active,
                    x_col=None, plane_mask=None):
        return ctx.row_exchange(self._propose(ctx, f_col, x_col))


class BottomUpPolicy(TraversalPolicy):
    name = "bottom_up"
    starts_bottom_up = True
    uses_top_down = False
    uses_bottom_up = True

    def propose_batch(self, expand, block, value, frontier, use_bu,
                      alg=None, x=None, plane_mask=None):
        # pull: the backend probes the *packed* frontier bitmap (the
        # representation switch; kernels/spmv's vertical width-1 gather, or
        # spmv_pull_min itself on the ELL slab), and only destinations in
        # the algebra's pull mask accumulate candidates
        mask = (value < 0) if alg is None else alg.pull_mask(value)
        if plane_mask is not None:
            mask = mask & plane_mask[:, None]
        if alg is None or alg.payload_is_id:
            return expand.pull_planes(block, frontier, mask)
        return expand.pull_value_planes(block, frontier, mask, x, alg)

    def expand_dist(self, ctx, value, f_col, use_bu, active,
                    x_col=None, plane_mask=None):
        alg = ctx.algebra
        # pull-mask membership of the whole row slice, gathered as bitmap
        # planes over the grid row — this replaces the id-stream ALLTOALLV.
        # Exhausted planes are masked out: their permanent unreached set
        # (often most of the graph) must not escalate the bucket consensus
        # the surviving planes' gather pays for, and the host replay prices
        # inactive planes as empty.
        mask = (value < 0) if alg is None else alg.pull_mask(value)
        pm = active if plane_mask is None else (plane_mask & active)
        unreached = ctx.unreached_gather(mask & pm[:, None])  # (B, n_r) bool
        if alg is None or alg.payload_is_id:
            # candidates stay column-LOCAL so the wire payload bit-packs at
            # the static column-width class; the receiver globalizes per
            # sender
            local = ctx.expand.pull_planes(ctx.block, f_col, unreached)
        else:
            local = ctx.expand.pull_value_planes(
                ctx.block, f_col, unreached, x_col, alg,
                row_base=ctx.row_base, col_base=ctx.col_index * ctx.n_c,
            )
        return ctx.row_exchange_bu(local.reshape(-1, ctx.c, ctx.s))


class DirectionOptPolicy(TraversalPolicy):
    """Beamer-style per-level switch between push and pull, per source.

    The per-plane direction flags live in the level-loop carry; both
    branches are in the traced program (``lax.cond``) and the flags are
    group-uniform because they derive from the globally ``psum``-ed
    per-plane frontier counts — the same consensus shape the
    AdaptiveExchange uses for bucket dispatch.  Each source plane switches
    independently: planes routed to the direction a branch does not serve
    ride it as masked (empty) planes, and a branch whose plane set is empty
    is skipped entirely at run time (its collectives still lower, so the
    CommStats ledger and HLO stay 1:1).
    """

    name = "direction_opt"
    uses_top_down = True
    uses_bottom_up = True

    def __init__(self):
        self._td = TopDownPolicy()
        self._bu = BottomUpPolicy()

    def propose_batch(self, expand, block, value, frontier, use_bu,
                      alg=None, x=None, plane_mask=None):
        # mirror expand_dist: ONE gated pass per direction over all planes.
        # A per-plane lax.cond would turn into a select that runs both O(m)
        # expansions every level — even for a scalar root.  Planes routed
        # to the direction a pass does not serve ride it masked-empty, as
        # in the distributed exchange.
        b, n = value.shape
        empty = INF if alg is None else alg.empty
        combine = jnp.minimum if alg is None else alg.combine
        act = jnp.any(frontier, axis=1)
        td_mask = (~use_bu) & act
        bu_mask = use_bu & act
        empty_planes = lambda: jnp.full((b, n), empty, jnp.int32)  # noqa: E731
        td = jax.lax.cond(
            jnp.any(td_mask),
            lambda: self._td.propose_batch(
                expand, block, value, frontier & td_mask[:, None], use_bu,
                alg=alg, x=x,
            ),
            empty_planes,
        )
        # the pull pass's mask is restricted to its planes, so it proposes
        # nothing for planes riding the push direction
        bu = jax.lax.cond(
            jnp.any(bu_mask),
            lambda: self._bu.propose_batch(
                expand, block, value, frontier & bu_mask[:, None], use_bu,
                alg=alg, x=x, plane_mask=bu_mask,
            ),
            empty_planes,
        )
        return combine(td, bu)

    def expand_dist(self, ctx, value, f_col, use_bu, active,
                    x_col=None, plane_mask=None):
        b = value.shape[0]
        alg = ctx.algebra
        empty = INF if alg is None else alg.empty
        combine = jnp.minimum if alg is None else alg.combine
        td_mask = (~use_bu) & active
        bu_mask = use_bu & active
        empty_planes = lambda: jnp.full((b, ctx.s), empty, jnp.int32)  # noqa: E731
        td = jax.lax.cond(
            jnp.any(td_mask),
            lambda: self._td.expand_dist(
                ctx, value, f_col & td_mask[:, None], use_bu, active,
                x_col=x_col,
            ),
            empty_planes,
        )
        # the pull pass's plane mask keeps push-direction planes out of the
        # unreached bitmap (and hence out of the pull wire's content)
        bu = jax.lax.cond(
            jnp.any(bu_mask),
            lambda: self._bu.expand_dist(
                ctx, value, f_col & bu_mask[:, None], use_bu, active,
                x_col=x_col, plane_mask=bu_mask,
            ),
            empty_planes,
        )
        return combine(td, bu)

    def next_direction(self, oracle, count, use_bu, m_f=None, m_u=None,
                       growing=None):
        return oracle.next_direction(count, use_bu, m_f=m_f, m_u=m_u,
                                     growing=growing)


def level_once(src, dst, n, policy: TraversalPolicy, oracle: DensityOracle,
               state, deg=None, expand=None, block=None, alg=None):
    """One single-device traversal level over every source plane.

    The single shared implementation behind ``bfs()`` / ``bfs_levels()`` /
    ``traverse()`` — ``state`` is any NamedTuple with value / level /
    frontier (all ``(B, n)``) / depth / active / use_bu / counts (``(B,)``)
    / aux fields.  The policy proposal runs plane-batched
    (``propose_batch``) through the local-expansion backend ``expand`` over
    its prepared ``block`` (default: the COO backend over the flat
    ``src``/``dst`` edge arrays); the per-plane popcounts come from one
    plane-blocked kernel call.  ``deg``, if given, is the (n,) degree
    vector feeding the anticipatory Beamer ``m_f`` signal (gated on a
    growing frontier, via the counts carry) into the per-plane direction
    decision — and the plus-times algebra's per-source ``x = v/deg``.
    ``alg`` is the :class:`repro.core.algebra.FrontierAlgebra`; ``None``
    keeps the historical min-parent BFS triple.
    """
    if expand is None:
        expand = expand_mod.resolve("coo")
    if block is None:
        block = expand.local_block(src, dst, (), n, n)
    x = None
    if alg is not None and alg.needs_values:
        x = alg.source_values(state.value, deg)
    proposed = policy.propose_batch(
        expand, block, state.value, state.frontier, state.use_bu,
        alg=alg, x=x,
    )
    if alg is None:
        new = (proposed < INF) & (state.value < 0)
        value = jnp.where(new, proposed, state.value)
    else:
        value, new = alg.update(state.value, proposed, state.depth, n)
    counts_new = oracle.plane_counts(new)
    m_f = m_u = growing = None
    if deg is not None and (alg is None or alg.payload_is_id):
        m_f, m_u = edge_signals(deg, new, state.value)
        growing = counts_new > state.counts
    if alg is None:
        aux, frontier, counts = state.aux, new, counts_new
        alive = jnp.any(counts_new > 0)
    else:
        from repro.core.algebra import LOCAL_EXCHANGE

        aux, frontier, counts, alive = alg.post_update(
            LOCAL_EXCHANGE, state.aux, state.value, value, new,
            state.frontier, oracle.plane_counts,
        )
    return state._replace(
        value=value,
        level=jnp.where(new, state.depth + 1, state.level),
        frontier=frontier,
        depth=state.depth + 1,
        active=alive,
        use_bu=policy.next_direction(oracle, counts, state.use_bu,
                                     m_f=m_f, m_u=m_u, growing=growing),
        counts=counts,
        aux=aux,
    )


def resolve(name: str) -> TraversalPolicy:
    """Resolve a policy by name through the unified registry."""
    return wire_registry.traversal(name)


POLICIES = ("top_down", "bottom_up", "direction_opt")

for _p in (TopDownPolicy(), BottomUpPolicy(), DirectionOptPolicy()):
    wire_registry.register_traversal(_p)
del _p
