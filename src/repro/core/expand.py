"""Local-expansion backends: block storage as a first-class registry axis.

The communication plane made the *wire* pluggable (wire plans) and the
*direction* pluggable (traversal policies); this module does the same for
the third leg of a distributed BFS level — the **local expansion** that
turns the gathered frontier slice into per-destination candidate parents.
Data-structure choice dominates on-node BFS cost once communication is
optimized (Buluc & Madduri, arXiv:1104.4518), and the winning structure is
degree-dependent: dense ELL neighbor slabs stream through the Pallas SpMV
kernels, but hub rows make a slab-wide ELL unaffordable, so hubs want to
stay COO (Bisson et al., arXiv:1408.1605).  Three backends, resolved by
name through :func:`repro.comm.registry.expansion`:

* ``coo``    — the flat segment_min over the sentinel-padded edge arrays
  (the historical path, extracted here).
* ``ell``    — dense ``(rows, k)`` neighbor blocks driven through
  :mod:`repro.kernels.spmv` push/pull (``k`` covers the heaviest row).
* ``hybrid`` — per-block degree split: rows with degree <= ``k`` live in
  an ELL slab, the hub residue stays COO; the ``auto`` split picks ``k``
  from the block's degree histogram so ELL padding waste stays under a
  budget (:func:`repro.graphgen.builder.select_split_k`).  ``hybrid`` is
  also reachable under the alias ``auto``.

Every backend produces **bit-identical** candidates — each row's edge set
lives in exactly one structure, and the min-parent semiring commutes with
the split — and expansion is compute-local: no backend touches a
collective, so CommStats and the lowered HLO are invariant under backend
choice (asserted by tests/test_expansion.py).

The containers are built at partition time (:func:`repro.core.csr.ell_blocked`
/ :func:`repro.core.csr.hybrid_blocked`) with static, sentinel-padded
shapes and are sharded alongside the COO edge arrays by
:func:`repro.core.distributed_bfs.shard_blocked`.
"""

from __future__ import annotations

import weakref
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import registry as wire_registry
from repro.comm.formats import INF
from repro.core import csr as csrmod
from repro.graphgen import builder
from repro.kernels.bitpack import ops as bp_ops
from repro.kernels.spmv import ops as spmv_ops
from repro.kernels.spmv import ref as spmv_ref

#: name aliases accepted by :func:`resolve` (the example's ``--expand auto``)
ALIASES = {"auto": "hybrid"}


def resolve(name: str):
    """Resolve an expansion backend by name through the unified registry."""
    return wire_registry.expansion(ALIASES.get(name, name))


BACKENDS = ("coo", "ell", "hybrid")


def _chunk_pad(m: int) -> int:
    return m + (-m) % 1024


def _pack_planes(bits: jax.Array) -> jax.Array:
    """(B, m) bool membership planes -> (B, chunk_pad(m)/32) packed words
    (the vertical width-1 layout every bitmap probe in the repo uses)."""
    b, m = bits.shape
    pad = (-m) % 1024
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((b, pad), bits.dtype)], axis=1)
    return bp_ops.pack_planes(bits.astype(jnp.uint32), 1)


class LocalBlock(NamedTuple):
    """One rank's expansion-ready storage (built inside ``shard_map``).

    ``src``/``dst`` hold COO edges — the whole block for the ``coo``
    backend, the hub residue for ``hybrid``, unused (but carried for the
    degree vector) for ``ell``; ``nbr`` is the dense ELL slab or ``None``.
    Sentinels follow the partition convention: ``n_cols`` on the source
    side, ``n_rows`` on the destination side.
    """

    src: jax.Array  # (e,) column-local sources
    dst: jax.Array  # (e,) row-local destinations
    nbr: jax.Array | None  # (n_rows, k) ELL slab, sentinel n_cols
    n_rows: int
    n_cols: int


def _coo_push(src, dst, n_rows: int, n_cols: int, f):
    """(B, n_cols) frontier planes -> (B, n_rows) min frontier source per
    destination (column-LOCAL ids) via masked segment_min over the edges."""

    def one(fp):
        act = fp[jnp.clip(src, 0, n_cols - 1)] & (src < n_cols)
        cand = jnp.where(act, src, INF)
        return jax.ops.segment_min(cand, dst, num_segments=n_rows + 1)[:n_rows]

    return jax.vmap(one)(f)


def _coo_pull(src, dst, n_rows: int, n_cols: int, f, unreached):
    """Pull over COO edges: the frontier is probed through its *packed*
    bitmap (the representation switch the pull direction is about), and
    only unreached destinations accumulate candidates."""
    n_cp = _chunk_pad(n_cols)
    words = _pack_planes(f)

    def one(wp, un):
        hit = spmv_ref.frontier_bit(wp, src, n_cp) & (src < n_cols)
        pull = un[jnp.clip(dst, 0, n_rows - 1)] & (dst < n_rows)
        cand = jnp.where(hit & pull, src, INF)
        return jax.ops.segment_min(cand, dst, num_segments=n_rows + 1)[:n_rows]

    return jax.vmap(one)(words, unreached)


def _ell_push(nbr, n_cols: int, f):
    """ELL slab push through the plane-batched Pallas SpMV dispatch."""
    return spmv_ops.spmv_min_planes(nbr, _pack_planes(f), _chunk_pad(n_cols))


def _ell_pull(nbr, n_cols: int, f, unreached):
    """ELL pull: resident frontier + unreached bitmaps, finished rows INF."""
    return spmv_ops.spmv_pull_min_planes(
        nbr, _pack_planes(f), _pack_planes(unreached), _chunk_pad(n_cols)
    )


def _coo_push_value(src, dst, n_rows, n_cols, f, x, alg, row_base, col_base):
    """Value-algebra push over COO edges: each active edge proposes
    ``alg.edge_message`` of its source's value, reduced per destination
    with the algebra's combine (column-LOCAL frontier, GLOBAL payload)."""

    def one(fp, xp):
        s_cl = jnp.clip(src, 0, n_cols - 1)
        act = fp[s_cl] & (src < n_cols)
        msg = alg.edge_message(xp[s_cl], src + col_base, dst + row_base)
        cand = jnp.where(act, msg, alg.empty)
        return alg.segment_combine(cand, dst, n_rows + 1)[:n_rows]

    return jax.vmap(one)(f, x)


def _coo_pull_value(src, dst, n_rows, n_cols, f, unreached, x, alg,
                    row_base, col_base):
    """Value-algebra pull over COO edges: the frontier is probed through
    its packed bitmap and only ``unreached``-masked destinations (the
    algebra's pull mask) accumulate candidates."""
    n_cp = _chunk_pad(n_cols)
    words = _pack_planes(f)

    def one(wp, un, xp):
        s_cl = jnp.clip(src, 0, n_cols - 1)
        hit = spmv_ref.frontier_bit(wp, src, n_cp) & (src < n_cols)
        pull = un[jnp.clip(dst, 0, n_rows - 1)] & (dst < n_rows)
        msg = alg.edge_message(xp[s_cl], src + col_base, dst + row_base)
        cand = jnp.where(hit & pull, msg, alg.empty)
        return alg.segment_combine(cand, dst, n_rows + 1)[:n_rows]

    return jax.vmap(one)(words, unreached, x)


def _ell_push_value(nbr, n_cols: int, f, x, alg, row_base, col_base):
    """ELL value push through the op x reduce gspmm dispatch."""
    return spmv_ops.gspmm_planes(
        nbr, _pack_planes(f), x, _chunk_pad(n_cols), alg,
        row_base=row_base, col_base=col_base,
    )


def _ell_pull_value(nbr, n_cols: int, f, unreached, x, alg, row_base, col_base):
    """ELL value pull: masked destinations collapse to the empty sentinel."""
    return spmv_ops.gspmm_planes(
        nbr, _pack_planes(f), x, _chunk_pad(n_cols), alg,
        row_base=row_base, col_base=col_base, u_words=_pack_planes(unreached),
    )


class ExpansionBackend:
    """One local-expansion data structure (or a degree split over two).

    Host side, ``graph_arrays``/``block_arrays`` build the backend's extra
    device arrays — ``()`` for COO — from the flat edge list / the 2D
    :class:`~repro.core.csr.BlockedGraph`; each distributed array leads
    with the ``(R, C)`` grid axes so the driver can shard it like the edge
    blocks.  Device side, ``local_block`` assembles the per-rank
    :class:`LocalBlock` and ``push_planes``/``pull_planes`` expand all B
    frontier planes at once, returning ``(B, n_rows)`` column-local
    min-candidate ids (INF where none) — the traversal policy owns
    globalization and the wire.
    """

    name: str = ""
    #: trailing (per-rank) rank of each distributed extra array, after the
    #: leading (R, C) grid axes — lets the driver build shard specs without
    #: materializing the containers
    extra_ndims: tuple[int, ...] = ()

    def graph_arrays(self, src, dst, n: int) -> tuple[np.ndarray, ...]:
        return ()

    def block_arrays(self, bg: csrmod.BlockedGraph) -> tuple[np.ndarray, ...]:
        return ()

    def local_block(self, src, dst, extra, n_rows: int, n_cols: int) -> LocalBlock:
        raise NotImplementedError

    def push_planes(self, blk: LocalBlock, f):
        raise NotImplementedError

    def pull_planes(self, blk: LocalBlock, f, unreached):
        raise NotImplementedError

    def push_value_planes(self, blk: LocalBlock, f, x, alg, *, row_base=0,
                          col_base=0):
        """Value-algebra push: (B, n_cols) frontier + value planes ->
        (B, n_rows) combined candidate values (``alg.empty`` where none).
        ``row_base``/``col_base`` globalize the block-local ids for the
        algebra's edge messages."""
        raise NotImplementedError

    def pull_value_planes(self, blk: LocalBlock, f, unreached, x, alg, *,
                          row_base=0, col_base=0):
        raise NotImplementedError

    def describe(self, bg: csrmod.BlockedGraph) -> list[dict]:
        """Per-block split/padding report (the example's --expand print)."""
        return []


class CooExpansion(ExpansionBackend):
    name = "coo"

    def local_block(self, src, dst, extra, n_rows, n_cols):
        assert extra == (), extra
        return LocalBlock(src=src, dst=dst, nbr=None, n_rows=n_rows, n_cols=n_cols)

    def push_planes(self, blk, f):
        return _coo_push(blk.src, blk.dst, blk.n_rows, blk.n_cols, f)

    def pull_planes(self, blk, f, unreached):
        return _coo_pull(blk.src, blk.dst, blk.n_rows, blk.n_cols, f, unreached)

    def push_value_planes(self, blk, f, x, alg, *, row_base=0, col_base=0):
        return _coo_push_value(
            blk.src, blk.dst, blk.n_rows, blk.n_cols, f, x, alg,
            row_base, col_base,
        )

    def pull_value_planes(self, blk, f, unreached, x, alg, *, row_base=0,
                          col_base=0):
        return _coo_pull_value(
            blk.src, blk.dst, blk.n_rows, blk.n_cols, f, unreached, x, alg,
            row_base, col_base,
        )


class EllExpansion(ExpansionBackend):
    name = "ell"
    extra_ndims = (2,)  # (n_r, k) slab

    def graph_arrays(self, src, dst, n):
        nbr, _ = builder.ell_graph_arrays(np.asarray(src), np.asarray(dst), n)
        return (nbr,)

    def block_arrays(self, bg):
        return (self._blocks(bg).nbr,)

    def _blocks(self, bg):
        return _graph_cached(self, bg, csrmod.ell_blocked)

    def local_block(self, src, dst, extra, n_rows, n_cols):
        (nbr,) = extra
        return LocalBlock(src=src, dst=dst, nbr=nbr, n_rows=n_rows, n_cols=n_cols)

    def push_planes(self, blk, f):
        return _ell_push(blk.nbr, blk.n_cols, f)

    def pull_planes(self, blk, f, unreached):
        return _ell_pull(blk.nbr, blk.n_cols, f, unreached)

    def push_value_planes(self, blk, f, x, alg, *, row_base=0, col_base=0):
        return _ell_push_value(
            blk.nbr, blk.n_cols, f, x, alg, row_base, col_base
        )

    def pull_value_planes(self, blk, f, unreached, x, alg, *, row_base=0,
                          col_base=0):
        return _ell_pull_value(
            blk.nbr, blk.n_cols, f, unreached, x, alg, row_base, col_base
        )

    def describe(self, bg):
        blocks = self._blocks(bg)
        waste = blocks.padding_ratio()
        return [
            {"block": (i, j), "split_k": int(blocks.split_k[i, j]),
             "padding_ratio": float(waste[i, j])}
            for i in range(bg.part.rows) for j in range(bg.part.cols)
        ]


class HybridExpansion(ExpansionBackend):
    """Degree-split COO/ELL: low-degree rows on the slab, hubs in COO."""

    name = "hybrid"
    extra_ndims = (2, 1, 1)  # (n_r, k) slab + (r_cap,) residue src/dst

    def __init__(self, waste_budget: float = 0.5, split_k: int | None = None):
        self.waste_budget = waste_budget
        self.split_k = split_k

    def graph_arrays(self, src, dst, n):
        nbr, res_s, res_d, _ = builder.hybrid_graph_arrays(
            np.asarray(src), np.asarray(dst), n,
            waste_budget=self.waste_budget, split_k=self.split_k,
        )
        return (nbr, res_s, res_d)

    def _blocks(self, bg):
        return _graph_cached(
            self, bg,
            lambda b: csrmod.hybrid_blocked(
                b, waste_budget=self.waste_budget, split_k=self.split_k
            ),
        )

    def block_arrays(self, bg):
        h = self._blocks(bg)
        return (h.nbr, h.res_src, h.res_dst)

    def local_block(self, src, dst, extra, n_rows, n_cols):
        nbr, res_src, res_dst = extra
        return LocalBlock(
            src=res_src, dst=res_dst, nbr=nbr, n_rows=n_rows, n_cols=n_cols
        )

    def push_planes(self, blk, f):
        return jnp.minimum(
            _ell_push(blk.nbr, blk.n_cols, f),
            _coo_push(blk.src, blk.dst, blk.n_rows, blk.n_cols, f),
        )

    def pull_planes(self, blk, f, unreached):
        return jnp.minimum(
            _ell_pull(blk.nbr, blk.n_cols, f, unreached),
            _coo_pull(blk.src, blk.dst, blk.n_rows, blk.n_cols, f, unreached),
        )

    def push_value_planes(self, blk, f, x, alg, *, row_base=0, col_base=0):
        # each row's edge set lives in exactly one structure, so the
        # algebra's combine (min OR sum) merges the two halves exactly
        return alg.combine(
            _ell_push_value(blk.nbr, blk.n_cols, f, x, alg, row_base, col_base),
            _coo_push_value(
                blk.src, blk.dst, blk.n_rows, blk.n_cols, f, x, alg,
                row_base, col_base,
            ),
        )

    def pull_value_planes(self, blk, f, unreached, x, alg, *, row_base=0,
                          col_base=0):
        return alg.combine(
            _ell_pull_value(
                blk.nbr, blk.n_cols, f, unreached, x, alg, row_base, col_base
            ),
            _coo_pull_value(
                blk.src, blk.dst, blk.n_rows, blk.n_cols, f, unreached, x, alg,
                row_base, col_base,
            ),
        )

    def describe(self, bg):
        h = self._blocks(bg)
        waste = h.padding_ratio()
        return [
            {"block": (i, j), "split_k": int(h.split_k[i, j]),
             "padding_ratio": float(waste[i, j]),
             "residue_edges": int((h.res_src[i, j] < bg.part.n_c).sum())}
            for i in range(bg.part.rows) for j in range(bg.part.cols)
        ]


def _graph_cached(backend, bg, build):
    """One-entry per-backend container cache keyed on graph identity.

    Callers rebuild the same ``BlockedGraph``'s containers repeatedly (one
    ``shard_blocked`` per wire mode in the example, plus ``describe``);
    the O(m) host-side build only needs to run once.  Identity is checked
    through a weakref so a recycled ``id`` after garbage collection cannot
    alias a different graph.
    """
    cached = getattr(backend, "_graph_cache", None)
    if cached is not None and cached[0]() is bg:
        return cached[1]
    blocks = build(bg)
    backend._graph_cache = (weakref.ref(bg), blocks)
    return blocks


for _b in (CooExpansion(), EllExpansion(), HybridExpansion()):
    wire_registry.register_expansion(_b)
del _b
