"""Single-device level-synchronous BFS (paper Algorithm 2, one processor).

Edge-centric SpMV formulation: one BFS level is a masked ``segment_min`` of
candidate parents over the symmetric COO edge list — the linear-algebra view
the paper itself uses (``t = A (x) f`` over a min-parent semiring), with the
GPU warp-queue mechanics replaced by fully-vectorizable segment reductions
(DESIGN.md §3, hardware adaptation).

Direction optimization (Beamer, paper §3.1): in the vectorized formulation
both directions touch all edges, so the *work* saving of bottom-up does not
apply; what survives on TPU is the *representation* switch (dense bitmap vs
sparse id list) which drives the compressed-exchange bucket choice in the
distributed version.  ``bfs_levels`` therefore tracks frontier density per
level and reports which representation each level would use.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


class BFSResult(NamedTuple):
    parent: jax.Array  # (n,) int32, -1 = unreached, parent[root] = root
    level: jax.Array  # (n,) int32, -1 = unreached
    n_levels: jax.Array  # scalar int32


class _State(NamedTuple):
    parent: jax.Array
    level: jax.Array
    frontier: jax.Array  # (n,) bool
    depth: jax.Array
    active: jax.Array  # scalar bool


@functools.partial(jax.jit, static_argnames=("n",))
def bfs(src: jax.Array, dst: jax.Array, root: jax.Array, n: int) -> BFSResult:
    """BFS over a symmetric COO edge list (padding edges may use src=dst=n).

    Args:
      src/dst: (m,) int32 edge endpoints; entries equal to ``n`` are padding.
      root: scalar int32 source vertex.
      n: vertex count (static).
    """
    m = src.shape[0]
    del m

    def level_step(state: _State) -> _State:
        # t = A (x) f over the (min, parent-id) semiring: for every edge
        # (u, v) with u in frontier, propose parent u for v.
        cand = jnp.where(state.frontier[jnp.minimum(src, n - 1)] & (src < n), src, INF)
        proposed = jax.ops.segment_min(cand, dst, num_segments=n + 1)[:n]
        new = (proposed < INF) & (state.parent < 0)
        parent = jnp.where(new, proposed, state.parent)
        level = jnp.where(new, state.depth + 1, state.level)
        return _State(
            parent=parent,
            level=level,
            frontier=new,
            depth=state.depth + 1,
            active=jnp.any(new),
        )

    init = _State(
        parent=jnp.full((n,), -1, jnp.int32).at[root].set(root.astype(jnp.int32)),
        level=jnp.full((n,), -1, jnp.int32).at[root].set(0),
        frontier=jnp.zeros((n,), bool).at[root].set(True),
        depth=jnp.int32(0),
        active=jnp.bool_(True),
    )
    out = jax.lax.while_loop(lambda s: s.active, level_step, init)
    return BFSResult(parent=out.parent, level=out.level, n_levels=out.depth)


@functools.partial(jax.jit, static_argnames=("n", "max_levels"))
def bfs_levels(
    src: jax.Array, dst: jax.Array, root: jax.Array, n: int, max_levels: int = 64
) -> tuple[BFSResult, jax.Array]:
    """BFS + per-level frontier sizes (drives representation choice stats)."""

    def body(carry, _):
        state = carry
        state = jax.lax.cond(
            state.active,
            lambda s: _level_once(src, dst, n, s),
            lambda s: s._replace(active=jnp.bool_(False)),
            state,
        )
        return state, jnp.sum(state.frontier.astype(jnp.int32))

    init = _State(
        parent=jnp.full((n,), -1, jnp.int32).at[root].set(root.astype(jnp.int32)),
        level=jnp.full((n,), -1, jnp.int32).at[root].set(0),
        frontier=jnp.zeros((n,), bool).at[root].set(True),
        depth=jnp.int32(0),
        active=jnp.bool_(True),
    )
    out, sizes = jax.lax.scan(body, init, None, length=max_levels)
    return BFSResult(parent=out.parent, level=out.level, n_levels=out.depth), sizes


def _level_once(src, dst, n, state: _State) -> _State:
    cand = jnp.where(state.frontier[jnp.minimum(src, n - 1)] & (src < n), src, INF)
    proposed = jax.ops.segment_min(cand, dst, num_segments=n + 1)[:n]
    new = (proposed < INF) & (state.parent < 0)
    return _State(
        parent=jnp.where(new, proposed, state.parent),
        level=jnp.where(new, state.depth + 1, state.level),
        frontier=new,
        depth=state.depth + 1,
        active=jnp.any(new),
    )
