"""Single-device level-synchronous BFS (paper Algorithm 2, one processor).

Edge-centric SpMV formulation: one BFS level is a masked ``segment_min`` of
candidate parents over the symmetric COO edge list — the linear-algebra view
the paper itself uses (``t = A (x) f`` over a min-parent semiring), with the
GPU warp-queue mechanics replaced by fully-vectorizable segment reductions
(DESIGN.md §3, hardware adaptation).

Direction optimization (Beamer, paper §3.1) is a *policy*, resolved through
:mod:`repro.core.traversal`: ``top_down`` pushes from the frontier,
``bottom_up`` pulls through the packed frontier bitmap into unreached
vertices, and ``direction_opt`` switches per level on the popcount density
oracle.  In the vectorized formulation both directions touch all edges, so
the *work* saving of bottom-up does not apply; what survives on TPU is the
*representation* switch (dense bitmap vs sparse id list) which drives the
compressed-exchange bucket choice in the distributed version.  All policies
return identical parent/level arrays.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import traversal

INF = jnp.iinfo(jnp.int32).max


class BFSResult(NamedTuple):
    parent: jax.Array  # (n,) int32, -1 = unreached, parent[root] = root
    level: jax.Array  # (n,) int32, -1 = unreached
    n_levels: jax.Array  # scalar int32


class _State(NamedTuple):
    parent: jax.Array
    level: jax.Array
    frontier: jax.Array  # (n,) bool
    depth: jax.Array
    active: jax.Array  # scalar bool
    use_bu: jax.Array  # scalar bool: next level expands bottom-up


def _init_state(root: jax.Array, n: int, policy: traversal.TraversalPolicy) -> _State:
    return _State(
        parent=jnp.full((n,), -1, jnp.int32).at[root].set(root.astype(jnp.int32)),
        level=jnp.full((n,), -1, jnp.int32).at[root].set(0),
        frontier=jnp.zeros((n,), bool).at[root].set(True),
        depth=jnp.int32(0),
        active=jnp.bool_(True),
        use_bu=jnp.bool_(policy.starts_bottom_up),
    )


@functools.partial(jax.jit, static_argnames=("n", "policy", "max_levels"))
def bfs(
    src: jax.Array,
    dst: jax.Array,
    root: jax.Array,
    n: int,
    policy: str = "top_down",
    max_levels: int = 64,
) -> BFSResult:
    """BFS over a symmetric COO edge list (padding edges may use src=dst=n).

    Args:
      src/dst: (m,) int32 edge endpoints; entries equal to ``n`` are padding.
      root: scalar int32 source vertex.
      n: vertex count (static).
      policy: traversal policy name (see :mod:`repro.core.traversal`).
      max_levels: depth cap on the level loop — the same guard (and the
        same default) the distributed driver's ``DistBFSConfig.max_levels``
        applies, so an adversarial high-diameter edge list (a path graph,
        say) cannot keep the ``while_loop`` spinning for O(n) iterations.
        Vertices beyond the cap stay unreached (parent/level = -1); a
        truncated run is detectable as ``n_levels == max_levels`` — raise
        the cap for legitimately high-eccentricity graphs.
    """
    pol = traversal.resolve(policy)
    oracle = traversal.DensityOracle(n)
    out = jax.lax.while_loop(
        lambda s: s.active & (s.depth < max_levels),
        lambda s: traversal.level_once(src, dst, n, pol, oracle, s),
        _init_state(root, n, pol),
    )
    return BFSResult(parent=out.parent, level=out.level, n_levels=out.depth)


@functools.partial(jax.jit, static_argnames=("n", "max_levels", "policy"))
def bfs_levels(
    src: jax.Array,
    dst: jax.Array,
    root: jax.Array,
    n: int,
    max_levels: int = 64,
    policy: str = "top_down",
) -> tuple[BFSResult, jax.Array]:
    """BFS + per-level frontier sizes (drives representation choice stats).

    The ``scan`` length doubles as the depth cap: levels beyond
    ``max_levels`` are never expanded, mirroring ``bfs()``'s guard.
    """
    pol = traversal.resolve(policy)
    oracle = traversal.DensityOracle(n)

    def body(state, _):
        state = jax.lax.cond(
            state.active,
            lambda s: traversal.level_once(src, dst, n, pol, oracle, s),
            lambda s: s._replace(active=jnp.bool_(False)),
            state,
        )
        return state, jnp.sum(state.frontier.astype(jnp.int32))

    out, sizes = jax.lax.scan(body, _init_state(root, n, pol), None, length=max_levels)
    return BFSResult(parent=out.parent, level=out.level, n_levels=out.depth), sizes
