"""Single-device level-synchronous BFS (paper Algorithm 2, one processor).

Edge-centric SpMV formulation: one BFS level is a masked ``segment_min`` of
candidate parents over the symmetric COO edge list — the linear-algebra view
the paper itself uses (``t = A (x) f`` over a min-parent semiring), with the
GPU warp-queue mechanics replaced by fully-vectorizable segment reductions
(DESIGN.md §3, hardware adaptation).

Direction optimization (Beamer, paper §3.1) is a *policy*, resolved through
:mod:`repro.core.traversal`: ``top_down`` pushes from the frontier,
``bottom_up`` pulls through the packed frontier bitmap into unreached
vertices, and ``direction_opt`` switches per level on the popcount density
oracle, anticipated one level early by the Beamer ``m_f`` edge signal (the
degree vector is computed once before the level loop).  In the vectorized
formulation both directions touch all edges, so the *work* saving of
bottom-up does not apply; what survives on TPU is the *representation*
switch (dense bitmap vs sparse id list) which drives the compressed-exchange
bucket choice in the distributed version.  All policies return identical
parent/level arrays.

**Multi-source batches**: ``root`` may be a scalar (legacy single-source
shapes) or a ``(B,)`` vector of sources.  Batched runs widen every carry to
a leading plane axis — parent/level/frontier become ``(B, n)``, the
direction flag becomes per-source — and the level loop runs until every
plane's frontier is empty.  Results per plane are identical to ``B``
independent single-source runs.
"""

from __future__ import annotations

import functools
import weakref
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algebra as algebra_mod
from repro.core import expand as expand_mod
from repro.core import traversal

INF = jnp.iinfo(jnp.int32).max


class BFSResult(NamedTuple):
    parent: jax.Array  # (n,) | (B, n) int32, -1 = unreached, parent[root] = root
    level: jax.Array  # (n,) | (B, n) int32, -1 = unreached
    n_levels: jax.Array  # scalar int32 (batched: depth of the longest plane)


class _State(NamedTuple):
    value: jax.Array  # (B, n) algebra state plane (BFS: parent ids)
    level: jax.Array  # (B, n)
    frontier: jax.Array  # (B, n) bool
    depth: jax.Array
    active: jax.Array  # scalar bool: any plane still expanding
    use_bu: jax.Array  # (B,) bool: plane expands bottom-up next level
    counts: jax.Array  # (B,) int32 frontier sizes (m_f growing-guard carry)
    aux: tuple  # algebra-private carry (SSSP's pending set; () otherwise)


def validate_roots(roots, n: int):
    """Check root vertices (dtype, range, duplicates) -> int32 array.

    Shared by ``bfs()`` and the distributed driver.  Concrete inputs fail
    fast with a clear error instead of silently wrapping around in the
    ``parent.at[root]`` scatter; traced values (calls from inside ``jit``)
    skip the value checks but keep the shape/dtype contract.
    """
    if isinstance(roots, jax.core.Tracer):
        if roots.ndim > 1:
            raise ValueError(f"roots must be a scalar or (B,) vector, got "
                             f"shape {roots.shape}")
        if not jnp.issubdtype(roots.dtype, jnp.integer):
            raise TypeError(f"roots must be integers, got {roots.dtype}")
        if roots.ndim == 1 and roots.shape[0] == 0:  # static even when traced
            raise ValueError("roots must name at least one source vertex")
        return roots.astype(jnp.int32)
    arr = np.asarray(roots)
    if arr.ndim > 1:
        raise ValueError(f"roots must be a scalar or (B,) vector, got "
                         f"shape {arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise TypeError(f"roots must be integers, got {arr.dtype}")
    if arr.size == 0:
        raise ValueError("roots must name at least one source vertex")
    if arr.min(initial=0) < 0 or arr.max(initial=0) >= n:
        bad = arr[(arr < 0) | (arr >= n)]
        raise ValueError(
            f"roots out of range [0, {n}): {np.atleast_1d(bad)[:8].tolist()}"
        )
    if arr.ndim == 1 and np.unique(arr).size != arr.size:
        vals, counts = np.unique(arr, return_counts=True)
        raise ValueError(
            f"duplicate roots in batch: {vals[counts > 1][:8].tolist()} "
            "(each source plane must have a distinct root)"
        )
    return jnp.asarray(arr, jnp.int32)


def hub_roots(degrees, n_roots: int) -> np.ndarray:
    """The ``n_roots`` highest-degree vertices (stable order, argmax first).

    The one root-selection convention for multi-source batches: hub roots
    reach the dense core at the same depth, so the B frontier trajectories
    stay bucket-aligned and the shared-header amortization is not washed
    out by consensus escalation across planes.  Shared by the benchmark's
    acceptance rows (``benchmarks.bfs_comm.batch_roots``) and the example
    driver, so their batches name the same sources.
    """
    order = np.argsort(-np.asarray(degrees), kind="stable")
    return order[:n_roots].astype(np.int64)


def _init_state(roots: jax.Array, n: int, policy: traversal.TraversalPolicy,
                alg: algebra_mod.FrontierAlgebra) -> _State:
    b = roots.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    hit = idx[None, :] == roots[:, None]
    value, frontier = alg.init(hit, idx, roots.astype(jnp.int32), n)
    return _State(
        value=value,
        level=jnp.where(hit, 0, -1).astype(jnp.int32),
        frontier=frontier,
        depth=jnp.int32(0),
        active=jnp.bool_(True),
        use_bu=jnp.broadcast_to(jnp.bool_(policy.starts_bottom_up), (b,)),
        counts=jnp.ones((b,), jnp.int32),
        aux=alg.init_aux(frontier),
    )


_extra_cache = None  # (backend name, src ref, dst ref, n, device arrays)


def _expansion_extra(src, dst, n: int, expand: str):
    """Host-side backend containers for the single-device driver.

    The COO backend needs nothing beyond the edge arrays; ELL/hybrid build
    their slab/residue containers from the *concrete* edge list — calling
    with traced arrays fails with a clear error instead of a silent
    retrace-time rebuild.  The most recent build is cached by graph
    identity (weakrefs, mirroring the distributed driver's container
    cache) so a Graph500-style loop over many roots pays the O(m) numpy
    build and the host->device transfer once.
    """
    global _extra_cache
    backend = expand_mod.resolve(expand)
    if isinstance(src, jax.core.Tracer) or isinstance(dst, jax.core.Tracer):
        if backend.name != "coo":
            raise TypeError(
                f"expansion backend {expand!r} builds its block containers "
                "from concrete edge arrays; call bfs() outside jit or use "
                "expand='coo'"
            )
        return ()
    if backend.name == "coo":
        return ()
    c = _extra_cache
    if (c is not None and c[0] == backend.name and c[1]() is src
            and c[2]() is dst and c[3] == n):
        return c[4]
    extra = tuple(
        jnp.asarray(a) for a in backend.graph_arrays(np.asarray(src), np.asarray(dst), n)
    )
    try:
        _extra_cache = (backend.name, weakref.ref(src), weakref.ref(dst), n, extra)
    except TypeError:
        pass  # plain numpy inputs are not weakref-able; skip caching
    return extra


@functools.partial(
    jax.jit, static_argnames=("n", "policy", "max_levels", "expand", "algebra")
)
def _bfs_batched(src, dst, roots, n, policy, max_levels, expand, extra,
                 algebra="bfs"):
    pol = traversal.resolve(policy)
    alg = algebra_mod.resolve(algebra)
    backend = expand_mod.resolve(expand)
    block = backend.local_block(src, dst, extra, n, n)
    oracle = traversal.DensityOracle(n)
    # the degree vector is computed once before the level loop, and only
    # when something consumes it: the anticipatory direction oracle or the
    # plus-times algebra's x = v/deg source messages
    deg = None
    if (pol.uses_top_down and pol.uses_bottom_up) or alg.needs_deg:
        deg = traversal.degree_vector(src, dst, n, n)
    out = jax.lax.while_loop(
        lambda s: s.active & (s.depth < max_levels),
        lambda s: traversal.level_once(src, dst, n, pol, oracle, s, deg=deg,
                                       expand=backend, block=block, alg=alg),
        _init_state(roots, n, pol, alg),
    )
    return BFSResult(parent=alg.finalize(out.value), level=out.level,
                     n_levels=out.depth)


def bfs(
    src: jax.Array,
    dst: jax.Array,
    root: jax.Array,
    n: int,
    policy: str = "top_down",
    max_levels: int = 64,
    expand: str = "coo",
    algebra="bfs",
) -> BFSResult:
    """BFS over a symmetric COO edge list (padding edges may use src=dst=n).

    Args:
      src/dst: (m,) int32 edge endpoints; entries equal to ``n`` are padding.
      root: scalar int32 source vertex, or a ``(B,)`` batch of distinct
        sources — batched runs return ``(B, n)`` parent/level planes, each
        identical to the corresponding single-source run.
      n: vertex count (static).
      policy: traversal policy name (see :mod:`repro.core.traversal`).
      max_levels: depth cap on the level loop — the same guard (and the
        same default) the distributed driver's ``DistBFSConfig.max_levels``
        applies, so an adversarial high-diameter edge list (a path graph,
        say) cannot keep the ``while_loop`` spinning for O(n) iterations.
        Vertices beyond the cap stay unreached (parent/level = -1); a
        truncated run is detectable as ``n_levels == max_levels`` — raise
        the cap for legitimately high-eccentricity graphs.
      expand: local-expansion backend name (``coo`` | ``ell`` | ``hybrid``
        | ``auto``, see :mod:`repro.core.expand`) — all backends return
        bit-identical parent/level arrays.
      algebra: frontier algebra name or instance (``bfs`` | ``sssp`` |
        ``cc`` | ``pagerank``, see :mod:`repro.core.algebra`).  For value
        algebras the ``parent`` field of the result carries the finalized
        value plane (SSSP distances, CC labels, PageRank scores) and
        ``level`` the round each vertex last improved.
    """
    roots = validate_roots(root, n)
    squeeze = roots.ndim == 0
    extra = _expansion_extra(src, dst, n, expand)
    res = _bfs_batched(
        src, dst, jnp.atleast_1d(roots), n, policy, max_levels, expand, extra,
        algebra=algebra,
    )
    if squeeze:
        return BFSResult(res.parent[0], res.level[0], res.n_levels)
    return res


@functools.partial(
    jax.jit, static_argnames=("n", "max_levels", "policy", "expand")
)
def _bfs_levels_batched(src, dst, roots, n, max_levels, policy, expand, extra):
    pol = traversal.resolve(policy)
    alg = algebra_mod.resolve("bfs")
    backend = expand_mod.resolve(expand)
    block = backend.local_block(src, dst, extra, n, n)
    oracle = traversal.DensityOracle(n)
    deg = None
    if pol.uses_top_down and pol.uses_bottom_up:
        deg = traversal.degree_vector(src, dst, n, n)

    def body(state, _):
        state = jax.lax.cond(
            state.active,
            lambda s: traversal.level_once(src, dst, n, pol, oracle, s, deg=deg,
                                           expand=backend, block=block, alg=alg),
            lambda s: s._replace(active=jnp.bool_(False)),
            state,
        )
        return state, jnp.sum(state.frontier.astype(jnp.int32), axis=1)

    out, sizes = jax.lax.scan(
        body, _init_state(roots, n, pol, alg), None, length=max_levels
    )
    return BFSResult(parent=out.value, level=out.level, n_levels=out.depth), sizes


def bfs_levels(
    src: jax.Array,
    dst: jax.Array,
    root: jax.Array,
    n: int,
    max_levels: int = 64,
    policy: str = "top_down",
    expand: str = "coo",
) -> tuple[BFSResult, jax.Array]:
    """BFS + per-level frontier sizes (drives representation choice stats).

    The ``scan`` length doubles as the depth cap: levels beyond
    ``max_levels`` are never expanded, mirroring ``bfs()``'s guard.
    Batched roots return per-plane size columns: ``sizes[l, k]`` is plane
    ``k``'s frontier size after level ``l+1``.
    """
    roots = validate_roots(root, n)
    squeeze = roots.ndim == 0
    extra = _expansion_extra(src, dst, n, expand)
    res, sizes = _bfs_levels_batched(
        src, dst, jnp.atleast_1d(roots), n, max_levels, policy, expand, extra
    )
    if squeeze:
        return BFSResult(res.parent[0], res.level[0], res.n_levels), sizes[:, 0]
    return res, sizes
