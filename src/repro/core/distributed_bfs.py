"""2D-partitioned distributed BFS with adaptive compressed collectives
(paper Alg. 4).

One BFS level on the R x C grid (rank (i, j) holds block A_ij, owns vertex
chunk q = i*C + j of width s):

  1. **TransposeVector** (Alg. 2 l.4): ``ppermute`` moves owned frontier
     chunk q to the rank that needs it column-phase (rank (q % R, q // R)).
  2. **column phase** (ALLGATHERV + compress): all-gather of the frontier
     membership over the row axis assembles the column slice f_j; the wire
     representation is chosen per group by the bucket ladder — packed
     delta+PFOR16 id stream when sparse, width-1 bitmap when dense.
  3. **local expansion**: the traversal policy's direction — *push*
     (top-down: min candidate parents over the block's edges,
     t_i = A_ij (x) f_j) or *pull* (bottom-up: only unreached destinations
     accumulate, gated on an unreached-bitmap all-gather over the grid
     row) — dispatched through the *expansion backend* (``cfg.expand``):
     ``coo`` (flat segment_min over the padded edge arrays), ``ell``
     (dense neighbor slabs through the Pallas SpMV kernels), or ``hybrid``
     (per-block degree split; hubs stay COO).  Expansion is compute-local:
     backend choice changes no collective and no CommStats entry.
  4. **row phase**: top-down exchanges per-destination candidate subchunks
     (ALLTOALLV + compress — ids delta-packed, parent payloads bit-packed);
     bottom-up swaps the id streams for a found-bitmap + bit-packed-parent
     exchange whose wire cost is density-independent.  Receiver min-reduces
     into its owned chunk either way.
  5. frontier/parent/level update, global ``psum`` termination test; for
     ``direction_opt`` the same popcount count drives the next level's
     direction through the carry.

Modes are *wire plans* and traversal directions are *policies*, both
resolved through :mod:`repro.comm.registry`: mode 'raw' (uncompressed — the
paper's Baseline), 'bitmap', 'auto' (bucketed adaptive), 'btfly'
(ButterFly BFS: the row/unreached exchanges become log2(C) staged
``ppermute`` rounds that re-compress the merged stream per hop; any grid
width works — non-power-of-two C folds its overhang ranks into a first
stage) x policy 'top_down', 'bottom_up', 'direction_opt' (Beamer per-level
switch).  Every collective —
including the transpose permute and the termination psum — reports its wire
bytes through :class:`repro.comm.CommStats`, so the accounting can be
checked 1:1 against the collective operand sizes in the lowered HLO
(:func:`repro.launch.roofline.compare_comm_stats`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import AdaptiveExchange, CommStats, ThresholdPolicy
from repro.comm import collectives as comm_cc
from repro.comm import registry as wire_registry
from repro.core import algebra as algebra_mod
from repro.core import bfs, traversal
from repro.core import expand as expand_mod
from repro.core.csr import BlockedGraph, Partition2D

INF = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class DistBFSConfig:
    row_axes: tuple[str, ...] = ("data",)  # mesh axes spanning grid rows (R)
    col_axis: str = "model"  # mesh axis spanning grid columns (C)
    mode: str = "auto"  # wire-plan name: 'raw' | 'bitmap' | 'auto' | 'btfly'
    policy: str = "top_down"  # traversal: 'top_down' | 'bottom_up' | 'direction_opt'
    expand: str = "coo"  # local expansion: 'coo' | 'ell' | 'hybrid' | 'auto'
    #: frontier algebra: 'bfs' | 'sssp' | 'cc' | 'pagerank', or a
    #: FrontierAlgebra instance (custom delta/tol).  Phase names in the
    #: CommStats ledger are prefixed with the algebra's name.
    algebra: object = "bfs"
    alpha: float | None = None  # BU entry density; None = derive from the ladder
    beta: float = 0.05  # BU exit density (hysteresis)
    max_levels: int = 64

    @property
    def all_axes(self) -> tuple[str, ...]:
        return self.row_axes + (self.col_axis,)


def parent_width_class(n_c: int) -> int:
    """Smallest packing class covering column-local parent offsets."""
    from repro.comm.butterfly import width_class

    return width_class(n_c)


class _Carry(NamedTuple):
    value: jax.Array  # (B, s) int32 algebra state plane (BFS: parent ids)
    level: jax.Array  # (B, s) int32
    frontier: jax.Array  # (B, s) bool
    depth: jax.Array
    active: jax.Array  # scalar bool: any plane still expanding
    use_bu: jax.Array  # (B,) bool: plane expands bottom-up next level
    counts: jax.Array  # (B,) int32 global frontier sizes (psum consensus)
    aux: tuple  # algebra-private carry (SSSP's pending set; () otherwise)


def _bfs_local(
    src_l,
    dst_l,
    extra,
    roots,
    *,
    part: Partition2D,
    cfg: DistBFSConfig,
    stats: CommStats | None = None,
    threshold: ThresholdPolicy | None = None,
):
    """Per-rank body (inside shard_map). src_l/dst_l: (1,..,1,e_cap);
    ``extra``: the expansion backend's per-block containers (ELL slab /
    hybrid residue), same leading singleton grid axes; ``roots``: (B,)
    replicated source vertices — the batch dimension B is a first-class
    axis here, carried as (B, s) planes through every phase."""
    grid_nd = len(cfg.row_axes) + 1
    src_l = src_l.reshape(-1)
    dst_l = dst_l.reshape(-1)
    extra = tuple(a.reshape(a.shape[grid_nd:]) for a in extra)
    b = roots.shape[0]
    r, c, s = part.rows, part.cols, part.chunk
    n_r, n_c = part.n_r, part.n_c
    i = jax.lax.axis_index(cfg.row_axes)
    j = jax.lax.axis_index(cfg.col_axis)
    q = i * c + j
    base = q * s
    perm = part.transpose_perm()

    alg = algebra_mod.resolve(cfg.algebra)
    p = alg.name  # CommStats phase prefix ("bfs/..." stays the seed ledger)
    # the row wire's candidate payload: column-local parent offsets for the
    # id algebra, the algebra's value class otherwise
    p_width = alg.row_payload_width(n_c, part.n)

    policy = traversal.resolve(cfg.policy)
    adaptive = policy.uses_top_down and policy.uses_bottom_up
    alpha = cfg.alpha
    if alpha is None:
        # direction switch at the row ladder's sparse-capacity edge: one
        # oracle decides both the wire bucket and the traversal direction
        alpha = traversal.ladder_alpha(s, p_width, threshold=threshold)
    oracle = traversal.DensityOracle(part.n, alpha=alpha, beta=cfg.beta)

    # mode selection through the unified wire-plan registry: the plan builds
    # the adaptive exchanges (ladders, formats, engine, stats) each traversal
    # direction needs at this site; unused directions build nothing, so no
    # dead collectives reach the HLO or the CommStats ledger.  Every builder
    # gets the plane count: B frontier planes share each exchange's header
    # and bucket consensus.
    plan = wire_registry.wire_plan(cfg.mode)
    column_gather = plan.build_column(
        s, cfg.row_axes, r, b=b, policy=threshold, stats=stats,
        phase=f"{p}/column",
    )
    row_exchange = row_exchange_bu = unreached_gather = None
    if policy.uses_top_down:
        row_exchange = plan.build_row(
            s, cfg.col_axis, c, n_c, p_width, b=b,
            policy=threshold, stats=stats, phase=f"{p}/row", alg=alg,
        )
    if policy.uses_bottom_up:
        row_exchange_bu = plan.build_row_bu(
            s, cfg.col_axis, c, n_c, p_width, b=b,
            policy=threshold, stats=stats, phase=f"{p}/row-pull", alg=alg,
        )
        unreached_gather = plan.build_unreached(
            s, cfg.col_axis, c, b=b,
            policy=threshold, stats=stats, phase=f"{p}/unreached",
        )
    # non-adaptive exchanges report through the same engine facade; the
    # termination psum carries all B plane counts in one all-reduce (plus,
    # for adaptive policies, a float32 m_f/m_u companion — same total words
    # as stacking, but the edge dots cannot ride int32 at Graph500 scales)
    ex_transpose = AdaptiveExchange(f"{p}/transpose", cfg.all_axes, r * c, None,
                                    stats, planes=b)
    ex_term = AdaptiveExchange(f"{p}/termination", cfg.all_axes, r * c, None,
                               stats, planes=b)
    ex_values = None
    if alg.needs_values:
        # value algebras ride a second column phase: the owned value plane
        # takes the same transpose permute, then a dense int32 all-gather
        # assembles the (B, n_c) source-value slice next to the membership
        # bits (value-plane packing is width-32, so dense IS the packed
        # representation; the ledger prices it under "{p}/values")
        ex_values = AdaptiveExchange(f"{p}/values", cfg.row_axes, r, None,
                                     stats, planes=b)

    deg_own = None
    if (adaptive and alg.payload_is_id) or alg.needs_deg:
        # anticipatory direction oracle (Beamer m_f, id payloads only) and
        # the plus-times algebra's x = v/deg both need the owned-degree
        # vector: psum it ONCE before the level loop — one grid-row
        # all-reduce whose cost is shared by every source plane.  Gated on
        # actual consumption: a recorded-but-dead psum would be DCE'd from
        # the HLO and break the ledger reconciliation.
        ex_degree = AdaptiveExchange(f"{p}/degree", cfg.col_axis, c, None, stats)
        deg_slice = traversal.degree_vector(src_l, dst_l, n_c, n_r)
        deg_row = ex_degree.psum(deg_slice, fmt="degree")
        deg_own = jax.lax.dynamic_slice(deg_row, (j * s,), (s,))

    # local expansion through the backend: the block containers were built
    # at partition time and sharded next to the COO arrays; expansion is
    # compute-local, so backend choice cannot touch the CommStats ledger
    # or the collectives above
    backend = expand_mod.resolve(cfg.expand)
    block = backend.local_block(src_l, dst_l, extra, n_r, n_c)

    ctx = traversal.DistLevelCtx(
        expand=backend,
        block=block,
        n_r=n_r,
        n_c=n_c,
        s=s,
        c=c,
        col_index=j,
        row_exchange=row_exchange,
        row_exchange_bu=row_exchange_bu,
        unreached_gather=unreached_gather,
        algebra=alg,
        row_base=i * n_r,
    )

    idx_global = base + jnp.arange(s, dtype=jnp.int32)
    roots32 = roots.astype(jnp.int32)

    def level_step(carry: _Carry) -> _Carry:
        # 1. TransposeVector: all B frontier planes in one permute
        bits_t = ex_transpose.ppermute(carry.frontier, perm, fmt="membership")
        # 2. column phase: assemble f_j (B, n_c) membership planes — and,
        # for value algebras, the matching (B, n_c) source-value planes
        f_col = column_gather(bits_t)
        x_col = None
        if alg.needs_values:
            x_own = alg.source_values(carry.value, deg_own)
            x_t = ex_transpose.ppermute(x_own, perm, fmt="values")
            x_col = comm_cc.gather_values_planes(ex_values, x_t)
        # 3+4. policy-directed local expansion + row exchange (per-plane
        # direction; planes with empty frontiers ride as masked planes)
        reduced = policy.expand_dist(
            ctx, carry.value, f_col, carry.use_bu, carry.counts > 0,
            x_col=x_col,
        )
        # 5. fold candidates into the owned state through the algebra; the
        # psum-ed improvement counts feed the termination test and (for
        # direction_opt) each plane's direction
        value, new = alg.update(carry.value, reduced, carry.depth, part.n)
        m_f = m_u = None
        if adaptive and alg.payload_is_id:
            lm_f, lm_u = traversal.edge_signals(deg_own, new, carry.value)
            edges = ex_term.psum(
                jnp.stack([lm_f, lm_u], axis=1), fmt="termination", part="edges"
            )
            m_f, m_u = edges[:, 0], edges[:, 1]
        aux, frontier, counts, alive = alg.post_update(
            ex_term, carry.aux, carry.value, value, new, carry.frontier,
            oracle.plane_counts,
        )
        return _Carry(
            value=value,
            level=jnp.where(new, carry.depth + 1, carry.level),
            frontier=frontier,
            depth=carry.depth + 1,
            active=alive & (carry.depth + 1 < cfg.max_levels),
            use_bu=policy.next_direction(oracle, counts, carry.use_bu,
                                         m_f=m_f, m_u=m_u,
                                         growing=counts > carry.counts),
            counts=counts,
            aux=aux,
        )

    hit = idx_global[None, :] == roots32[:, None]  # (B, s)
    value0, frontier0 = alg.init(hit, idx_global, roots32, part.n)
    init = _Carry(
        value=value0,
        level=jnp.where(hit, 0, -1).astype(jnp.int32),
        frontier=frontier0,
        depth=jnp.int32(0),
        active=jnp.bool_(True),
        use_bu=jnp.broadcast_to(jnp.bool_(policy.starts_bottom_up), (b,)),
        counts=jnp.ones((b,), jnp.int32),
        aux=alg.init_aux(frontier0),
    )
    out = jax.lax.while_loop(lambda s_: s_.active, level_step, init)
    return alg.finalize(out.value), out.level, out.depth


def build_bfs(
    mesh: Mesh,
    bg: BlockedGraph | Partition2D,
    cfg: DistBFSConfig | None = None,
    *,
    stats: CommStats | None = None,
    threshold: ThresholdPolicy | None = None,
):
    """Compile the distributed BFS for a mesh. Returns fn(*blocks, root)
    -> (parent, level, n_levels) with outputs sharded over all axes.

    ``blocks`` are the sharded arrays :func:`shard_blocked` produced for
    ``cfg.expand`` — ``(src_l, dst_l)`` for the COO backend (the legacy
    signature), plus the backend's block containers (ELL slab / hybrid
    residue) otherwise; call as ``fn(*shard_blocked(...), root)``.

    ``root`` may be a scalar source (legacy ``(n,)`` outputs) or a ``(B,)``
    batch of distinct sources — batched calls return ``(B, n)`` parent and
    level planes, one consensus round and one wire header per exchange
    serving all B planes.  Roots are validated (dtype, range, duplicates)
    before dispatch; a wrong root fails with a clear error instead of the
    silent wraparound indexing of the ``idx == root`` scatter.

    ``bg`` may be a BlockedGraph (runnable) or a bare Partition2D (dry-run
    lowering against ShapeDtypeStructs).  ``stats``, if given, is filled at
    trace time with one entry per collective op the program emits (idempotent
    across retraces).  ``threshold`` tunes the bucket ladders' break-even
    pruning (default: the TPU-link ThresholdPolicy)."""
    cfg = cfg or DistBFSConfig(
        row_axes=tuple(mesh.axis_names[:-1]), col_axis=mesh.axis_names[-1]
    )
    wire_registry.wire_plan(cfg.mode)  # fail on unknown modes at build time
    policy = wire_registry.traversal(cfg.policy)  # ... and unknown policies
    backend = expand_mod.resolve(cfg.expand)  # ... and unknown backends
    algebra_mod.resolve(cfg.algebra)  # ... and unknown algebras
    part = bg if isinstance(bg, Partition2D) else bg.part
    assert part.rows == functools.reduce(
        lambda a, b: a * b, (mesh.shape[a] for a in cfg.row_axes)
    ), "grid rows must match row-axis product"
    assert part.cols == mesh.shape[cfg.col_axis]
    if cfg.mode in ("bitmap", "auto", "btfly") or policy.uses_bottom_up:
        assert part.chunk % 1024 == 0, (
            f"compressed modes and pull traversal need 1024-multiple chunks "
            f"(got s={part.chunk}); partition with chunk_multiple=1024"
        )

    blk_spec = P(*cfg.row_axes, cfg.col_axis, None)
    extra_specs = tuple(
        P(*cfg.row_axes, cfg.col_axis, *(None,) * nd) for nd in backend.extra_ndims
    )
    out_spec = P(None, cfg.all_axes)  # (B, n) planes, vertex axis sharded

    local = functools.partial(
        _bfs_local, part=part, cfg=cfg, stats=stats, threshold=threshold
    )
    mapped = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(blk_spec, blk_spec, extra_specs, P()),
        out_specs=(out_spec, out_spec, P()),
    )
    jitted = jax.jit(mapped)
    n_blocks = 2 + len(backend.extra_ndims)

    def run(*args):
        if len(args) != n_blocks + 1:
            raise TypeError(
                f"expansion backend {backend.name!r} expects "
                f"fn(*{n_blocks} block arrays, root), got {len(args)} args "
                "— pass everything shard_blocked returned"
            )
        *blocks, root = args
        roots = bfs.validate_roots(root, part.n_orig)
        squeeze = roots.ndim == 0
        parent, level, depth = jitted(
            blocks[0], blocks[1], tuple(blocks[2:]), jnp.atleast_1d(roots)
        )
        if squeeze:
            return parent[0], level[0], depth
        return parent, level, depth

    return run


def shard_blocked(mesh: Mesh, bg: BlockedGraph, cfg: DistBFSConfig | None = None):
    """Place the blocked edge arrays — and the expansion backend's block
    containers (ELL slab / hybrid residue for ``cfg.expand``) — on the
    mesh.  Returns ``(src, dst, *backend arrays)``; the COO default keeps
    the legacy two-tuple."""
    cfg = cfg or DistBFSConfig(
        row_axes=tuple(mesh.axis_names[:-1]), col_axis=mesh.axis_names[-1]
    )
    sizes = tuple(mesh.shape[a] for a in cfg.all_axes)
    spec = P(*cfg.row_axes, cfg.col_axis, None)
    sharding = NamedSharding(mesh, spec)
    src = jax.device_put(bg.src_local.reshape(sizes + (-1,)), sharding)
    dst = jax.device_put(bg.dst_local.reshape(sizes + (-1,)), sharding)
    backend = expand_mod.resolve(cfg.expand)
    extra = []
    for a, nd in zip(backend.block_arrays(bg), backend.extra_ndims):
        tail = a.shape[2:]
        assert len(tail) == nd, (a.shape, nd)
        esharding = NamedSharding(mesh, P(*cfg.row_axes, cfg.col_axis, *(None,) * nd))
        extra.append(jax.device_put(a.reshape(sizes + tail), esharding))
    return (src, dst, *extra)
