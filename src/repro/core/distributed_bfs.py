"""2D-partitioned distributed BFS with adaptive compressed collectives
(paper Alg. 4).

One BFS level on the R x C grid (rank (i, j) holds block A_ij, owns vertex
chunk q = i*C + j of width s):

  1. **TransposeVector** (Alg. 2 l.4): ``ppermute`` moves owned frontier
     chunk q to the rank that needs it column-phase (rank (q % R, q // R)).
  2. **column phase** (ALLGATHERV + compress): all-gather of the frontier
     membership over the row axis assembles the column slice f_j; the wire
     representation is chosen per group by the bucket ladder — packed
     delta+PFOR16 id stream when sparse, width-1 bitmap when dense.
  3. **local SpMV**: masked segment_min of candidate parents over the
     block's edges (t_i = A_ij (x) f_j over the min-parent semiring).
  4. **row phase** (ALLTOALLV + compress): per-destination candidate
     subchunks exchanged over the column axis, ids packed as in (2),
     parent payloads bit-packed at the static column-width class; receiver
     min-reduces into its owned chunk.
  5. frontier/parent/level update, global ``psum`` termination test.

Modes are *wire plans* resolved through :mod:`repro.comm.registry`:
'raw' (uncompressed id lists — the paper's Baseline), 'bitmap' (dense
1-bit membership), 'auto' (bucketed adaptive — the paper's compression +
adaptive-representation stack).  Every collective — including the
transpose permute and the termination psum — reports its wire bytes
through :class:`repro.comm.CommStats`, so the accounting can be checked
1:1 against the collective operand sizes in the lowered HLO
(:func:`repro.launch.roofline.compare_comm_stats`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import AdaptiveExchange, CommStats, ThresholdPolicy
from repro.comm import registry as wire_registry
from repro.core.csr import BlockedGraph, Partition2D
from repro.kernels.bitpack.ref import B_CLASSES

INF = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class DistBFSConfig:
    row_axes: tuple[str, ...] = ("data",)  # mesh axes spanning grid rows (R)
    col_axis: str = "model"  # mesh axis spanning grid columns (C)
    mode: str = "auto"  # wire-plan name: 'raw' | 'bitmap' | 'auto'
    max_levels: int = 64

    @property
    def all_axes(self) -> tuple[str, ...]:
        return self.row_axes + (self.col_axis,)


def parent_width_class(n_c: int) -> int:
    """Smallest packing class covering column-local parent offsets."""
    need = max((n_c - 1).bit_length(), 1)
    for b in B_CLASSES:
        if b >= need:
            return b
    return 32


class _Carry(NamedTuple):
    parent: jax.Array  # (s,) int32 global parent ids, -1 unreached
    level: jax.Array  # (s,) int32
    frontier: jax.Array  # (s,) bool
    depth: jax.Array
    active: jax.Array


def _bfs_local(
    src_l,
    dst_l,
    root,
    *,
    part: Partition2D,
    cfg: DistBFSConfig,
    stats: CommStats | None = None,
    policy: ThresholdPolicy | None = None,
):
    """Per-rank body (inside shard_map). src_l/dst_l: (1,..,1,e_cap)."""
    src_l = src_l.reshape(-1)
    dst_l = dst_l.reshape(-1)
    r, c, s = part.rows, part.cols, part.chunk
    n_r, n_c = part.n_r, part.n_c
    i = jax.lax.axis_index(cfg.row_axes)
    j = jax.lax.axis_index(cfg.col_axis)
    q = i * c + j
    base = q * s
    p_width = parent_width_class(n_c)
    perm = part.transpose_perm()

    # mode selection through the unified wire-plan registry: the plan builds
    # both adaptive exchanges (ladders, formats, engine, stats) for this site
    plan = wire_registry.wire_plan(cfg.mode)
    column_gather = plan.build_column(
        s, cfg.row_axes, r, policy=policy, stats=stats, phase="bfs/column"
    )
    row_exchange = plan.build_row(
        s, cfg.col_axis, c, p_width, policy=policy, stats=stats, phase="bfs/row"
    )
    # non-adaptive exchanges report through the same engine facade
    ex_transpose = AdaptiveExchange("bfs/transpose", cfg.all_axes, r * c, None, stats)
    ex_term = AdaptiveExchange("bfs/termination", cfg.all_axes, r * c, None, stats)

    idx_global = base + jnp.arange(s, dtype=jnp.int32)
    root32 = root.astype(jnp.int32)

    def level_step(carry: _Carry) -> _Carry:
        # 1. TransposeVector
        bits_t = ex_transpose.ppermute(carry.frontier, perm, fmt="membership")
        # 2. column phase: assemble f_j (n_c,) membership
        f_col = column_gather(bits_t)
        # 3. local SpMV over block edges
        active_e = f_col[jnp.clip(src_l, 0, n_c - 1)] & (src_l < n_c)
        cand = jnp.where(active_e, j * n_c + src_l, INF)
        prop = jax.ops.segment_min(cand, dst_l, num_segments=n_r + 1)[:n_r]
        # 4. row phase: exchange per-destination subchunks, min-reduce
        reduced = row_exchange(prop.reshape(c, s))
        # 5. update owned state
        new = (reduced < INF) & (carry.parent < 0)
        n_new = ex_term.psum(jnp.sum(new.astype(jnp.int32)), fmt="termination")
        return _Carry(
            parent=jnp.where(new, reduced, carry.parent),
            level=jnp.where(new, carry.depth + 1, carry.level),
            frontier=new,
            depth=carry.depth + 1,
            active=(n_new > 0) & (carry.depth + 1 < cfg.max_levels),
        )

    init = _Carry(
        parent=jnp.where(idx_global == root32, root32, jnp.int32(-1)),
        level=jnp.where(idx_global == root32, 0, -1).astype(jnp.int32),
        frontier=idx_global == root32,
        depth=jnp.int32(0),
        active=jnp.bool_(True),
    )
    out = jax.lax.while_loop(lambda s_: s_.active, level_step, init)
    return out.parent, out.level, out.depth


def build_bfs(
    mesh: Mesh,
    bg: BlockedGraph | Partition2D,
    cfg: DistBFSConfig | None = None,
    *,
    stats: CommStats | None = None,
    policy: ThresholdPolicy | None = None,
):
    """Compile the distributed BFS for a mesh. Returns fn(src_l, dst_l, root)
    -> (parent (n,), level (n,), n_levels) with outputs sharded over all axes.

    ``bg`` may be a BlockedGraph (runnable) or a bare Partition2D (dry-run
    lowering against ShapeDtypeStructs).  ``stats``, if given, is filled at
    trace time with one entry per collective op the program emits (idempotent
    across retraces).  ``policy`` tunes the bucket ladders' break-even
    pruning (default: the TPU-link ThresholdPolicy)."""
    cfg = cfg or DistBFSConfig(
        row_axes=tuple(mesh.axis_names[:-1]), col_axis=mesh.axis_names[-1]
    )
    wire_registry.wire_plan(cfg.mode)  # fail on unknown modes at build time
    part = bg if isinstance(bg, Partition2D) else bg.part
    assert part.rows == functools.reduce(
        lambda a, b: a * b, (mesh.shape[a] for a in cfg.row_axes)
    ), "grid rows must match row-axis product"
    assert part.cols == mesh.shape[cfg.col_axis]
    if cfg.mode in ("bitmap", "auto"):
        assert part.chunk % 1024 == 0, (
            f"compressed modes need 1024-multiple chunks (got s={part.chunk}); "
            "partition with chunk_multiple=1024"
        )

    blk_spec = P(*cfg.row_axes, cfg.col_axis, None)
    out_spec = P(cfg.all_axes)

    local = functools.partial(
        _bfs_local, part=part, cfg=cfg, stats=stats, policy=policy
    )
    mapped = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(blk_spec, blk_spec, P()),
        out_specs=(out_spec, out_spec, P()),
    )
    return jax.jit(mapped)


def shard_blocked(mesh: Mesh, bg: BlockedGraph, cfg: DistBFSConfig | None = None):
    """Place the blocked edge arrays on the mesh."""
    cfg = cfg or DistBFSConfig(
        row_axes=tuple(mesh.axis_names[:-1]), col_axis=mesh.axis_names[-1]
    )
    sizes = tuple(mesh.shape[a] for a in cfg.all_axes)
    spec = P(*cfg.row_axes, cfg.col_axis, None)
    sharding = NamedSharding(mesh, spec)
    src = jax.device_put(bg.src_local.reshape(sizes + (-1,)), sharding)
    dst = jax.device_put(bg.dst_local.reshape(sizes + (-1,)), sharding)
    return src, dst
