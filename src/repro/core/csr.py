"""Device-side graph containers and the 2D block partitioner (paper §2.6.2).

The 2D partition: an R x C processor grid; rank (i, j) owns adjacency block
``A_ij`` = edges (u -> v) with ``u`` in *column slice* j (width n/C) and
``v`` in *row slice* i (width n/R).  Vertex *ownership* (who stores
parent[v] / the frontier bit of v) follows the row-phase output layout:
the global vertex space is split into R*C chunks of size ``s = n/(R*C)``;
rank (i, j) owns chunk ``q = i*C + j``.

Static shapes: every per-rank edge block is padded to the same capacity
``e_cap`` with sentinel edges (src = n_c, dst = s_rows) that fall out of all
gathers/segment reductions — the TPU-native replacement for the paper's
"residuum" special cases (§7.2.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphgen.builder import (
    CSRGraph,
    _round_up,
    edge_degrees,
    ell_from_edges,
    select_split_k,
)


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Geometry of the R x C grid over n (padded) vertices."""

    n: int  # padded global vertex count
    n_orig: int  # pre-padding vertex count
    rows: int  # R
    cols: int  # C

    @property
    def n_r(self) -> int:  # row-slice width (vertices per grid row)
        return self.n // self.rows

    @property
    def n_c(self) -> int:  # column-slice width
        return self.n // self.cols

    @property
    def chunk(self) -> int:  # owned-chunk width s
        return self.n // (self.rows * self.cols)

    def owner(self, v: np.ndarray) -> np.ndarray:
        """Owned-chunk index q = v // s; rank (q // C, q % C)."""
        return v // self.chunk

    def transpose_perm(self) -> list[tuple[int, int]]:
        """ppermute pairs implementing the paper's TransposeVector (Alg. 2 l.4).

        Rank p = i*C + j owns chunk q = p.  The column phase needs rank
        (i, j) to hold chunk j*R + i (so the column-j all-gather assembles
        the contiguous column slice).  Returns (src_rank, dst_rank) pairs
        over the row-major linearized grid.
        """
        pairs = []
        r, c = self.rows, self.cols
        for i in range(r):
            for j in range(c):
                src = i * c + j  # owns chunk q = src
                q = src
                # chunk q is needed (in column phase) by rank (i', j') with
                # j'*R + i' = q  =>  j' = q // R, i' = q % R
                jp, ip = q // r, q % r
                dst = ip * c + jp
                pairs.append((src, dst))
        return pairs


@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    """2D-blocked edge arrays, shaped (R, C, e_cap) with local indices.

    ``src_local`` indexes into the column slice [0, n_c); ``dst_local`` into
    the row slice [0, n_r).  Padding edges use (n_c, n_r) sentinels.
    """

    part: Partition2D
    src_local: np.ndarray  # (R, C, e_cap) int32
    dst_local: np.ndarray  # (R, C, e_cap) int32
    e_counts: np.ndarray  # (R, C) int64 true edge counts per block
    m_input: int

    @property
    def e_cap(self) -> int:
        return int(self.src_local.shape[-1])


@dataclasses.dataclass(frozen=True)
class ELLBlocks:
    """Dense destination-major neighbor slabs, one per 2D block.

    ``nbr[i, j]`` is the ``(n_r, k)`` ELL slab of block ``A_ij``: row ``v``
    (row-local destination) lists its column-local frontier-side neighbors,
    sentinel-padded with ``n_c`` (a vertex id that never hits a frontier
    bitmap).  Shapes are static across blocks — the slab width is the max
    over blocks, rounded to the SpMV kernel's degree chunk — so the arrays
    shard alongside the COO edge arrays.
    """

    part: Partition2D
    nbr: np.ndarray  # (R, C, n_r, k) int32, sentinel n_c
    split_k: np.ndarray  # (R, C) int32 per-block degree split

    @property
    def k(self) -> int:
        return int(self.nbr.shape[-1])

    def padding_ratio(self) -> np.ndarray:
        """(R, C) fraction of slab slots holding sentinels (ELL waste)."""
        slots = self.nbr.shape[-2] * self.nbr.shape[-1]
        pad = (self.nbr == self.part.n_c).sum(axis=(-2, -1))
        return pad / slots


@dataclasses.dataclass(frozen=True)
class HybridBlocks:
    """Per-block degree-split COO/ELL storage (Bisson et al.'s hub split).

    Rows with degree <= the block's ``split_k`` live in the shared-width
    ELL slab; the hub residue keeps its edges in sentinel-padded COO arrays
    of one static capacity, so every array shards like the flat edge
    blocks.  ``min(slab expansion, residue expansion)`` is bit-identical to
    the flat segment_min because each row's edges live in exactly one of
    the two structures.
    """

    part: Partition2D
    nbr: np.ndarray  # (R, C, n_r, k) int32, sentinel n_c
    res_src: np.ndarray  # (R, C, r_cap) int32, sentinel n_c
    res_dst: np.ndarray  # (R, C, r_cap) int32, sentinel n_r
    split_k: np.ndarray  # (R, C) int32 per-block degree split

    @property
    def k(self) -> int:
        return int(self.nbr.shape[-1])

    @property
    def r_cap(self) -> int:
        return int(self.res_src.shape[-1])

    def padding_ratio(self) -> np.ndarray:
        """(R, C) fraction of slab slots holding sentinels (ELL waste)."""
        slots = self.nbr.shape[-2] * self.nbr.shape[-1]
        pad = (self.nbr == self.part.n_c).sum(axis=(-2, -1))
        return pad / slots


def _block_degrees(src: np.ndarray, dst: np.ndarray, part: Partition2D) -> np.ndarray:
    return edge_degrees(src, dst, part.n_r, part.n_c)


def ell_slab_width(bg: BlockedGraph, deg_multiple: int = 8) -> int:
    """The slab width :func:`ell_blocked` will use: the max row degree over
    ALL blocks, rounded to the SpMV degree chunk.  The single place the
    pure-ELL affordability estimate lives, so memory guards (the benchmark's
    slab budget) cannot drift from what the container actually allocates."""
    part = bg.part
    max_deg = max(
        int(_block_degrees(bg.src_local[i, j], bg.dst_local[i, j], part).max(initial=0))
        for i in range(part.rows)
        for j in range(part.cols)
    )
    return _round_up(max(max_deg, 1), deg_multiple)


def ell_blocked(bg: BlockedGraph, deg_multiple: int = 8) -> ELLBlocks:
    """Pure-ELL containers: one slab width covering every block's heaviest
    row — affordable only when the degree distribution is flat; hub-heavy
    blocks want :func:`hybrid_blocked`."""
    part = bg.part
    r, c = part.rows, part.cols
    k = ell_slab_width(bg, deg_multiple)
    nbr = np.empty((r, c, part.n_r, k), np.int32)
    for i in range(r):
        for j in range(c):
            slab, res_s, _ = ell_from_edges(
                bg.src_local[i, j], bg.dst_local[i, j], part.n_r, part.n_c, k
            )
            assert res_s.size == 0, "pure ELL must cover every row"
            nbr[i, j] = slab
    return ELLBlocks(part=part, nbr=nbr, split_k=np.full((r, c), k, np.int32))


def hybrid_blocked(
    bg: BlockedGraph,
    waste_budget: float = 0.5,
    split_k: int | None = None,
    deg_multiple: int = 8,
    res_multiple: int = 1024,
) -> HybridBlocks:
    """Per-block degree-split containers built at partition time.

    Each block's split ``k`` comes from its own degree histogram
    (:func:`repro.graphgen.builder.select_split_k`, keeping ELL padding
    waste under ``waste_budget``) unless a fixed ``split_k`` is forced; the
    slab width and residue capacity are the max over blocks so shapes stay
    static for ``shard_map``.
    """
    part = bg.part
    r, c = part.rows, part.cols
    ks = np.empty((r, c), np.int32)
    for i in range(r):
        for j in range(c):
            deg = _block_degrees(bg.src_local[i, j], bg.dst_local[i, j], part)
            ks[i, j] = split_k or select_split_k(deg, waste_budget, deg_multiple)
    width = _round_up(int(ks.max(initial=1)), deg_multiple)
    slabs = np.empty((r, c, part.n_r, width), np.int32)
    residues = []
    for i in range(r):
        for j in range(c):
            slab, res_s, res_d = ell_from_edges(
                bg.src_local[i, j], bg.dst_local[i, j], part.n_r, part.n_c,
                int(ks[i, j]), width=width,
            )
            slabs[i, j] = slab
            residues.append((res_s, res_d))
    r_cap = _round_up(max(max(s.size for s, _ in residues), 1), res_multiple)
    res_src = np.full((r, c, r_cap), part.n_c, np.int32)
    res_dst = np.full((r, c, r_cap), part.n_r, np.int32)
    for b, (res_s, res_d) in enumerate(residues):
        i, j = divmod(b, c)
        res_src[i, j, : res_s.size] = res_s
        res_dst[i, j, : res_d.size] = res_d
    return HybridBlocks(
        part=part, nbr=slabs, res_src=res_src, res_dst=res_dst, split_k=ks
    )


def padded_geometry(n: int, rows: int, cols: int,
                    chunk_multiple: int = 1024) -> tuple[int, int]:
    """(padded n, chunk width s) that :func:`partition_2d` will produce for
    an ``n``-vertex graph — the single place the padding rule lives, so
    artifact writers (BENCH_comm.json's byte-model geometry) cannot drift
    from the replay's actual partition."""
    n_pad = _round_up(max(n, rows * cols), rows * cols * chunk_multiple)
    return n_pad, n_pad // (rows * cols)


def partition_2d(
    g: CSRGraph,
    rows: int,
    cols: int,
    chunk_multiple: int = 1024,
    e_cap_multiple: int = 1024,
) -> BlockedGraph:
    """Partition a CSR graph onto an R x C grid with static-capacity blocks.

    ``chunk_multiple`` keeps the owned-chunk width s a multiple of the
    bit-packing chunk (1024) so compressed exchanges stay lane-aligned.
    """
    n, _ = padded_geometry(g.n, rows, cols, chunk_multiple)
    part = Partition2D(n=n, n_orig=g.n, rows=rows, cols=cols)
    src, dst = g.src.astype(np.int64), g.dst.astype(np.int64)

    bj = src // part.n_c  # block column of each edge
    bi = dst // part.n_r  # block row
    block = bi * cols + bj
    order = np.argsort(block, kind="stable")
    src, dst, block = src[order], dst[order], block[order]
    counts = np.bincount(block, minlength=rows * cols)
    e_cap = _round_up(max(int(counts.max()), 1), e_cap_multiple)

    src_l = np.full((rows * cols, e_cap), part.n_c, dtype=np.int32)
    dst_l = np.full((rows * cols, e_cap), part.n_r, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in range(rows * cols):
        s0, cnt = starts[b], counts[b]
        if cnt == 0:
            continue
        i, j = divmod(b, cols)
        src_l[b, :cnt] = (src[s0 : s0 + cnt] - j * part.n_c).astype(np.int32)
        dst_l[b, :cnt] = (dst[s0 : s0 + cnt] - i * part.n_r).astype(np.int32)

    return BlockedGraph(
        part=part,
        src_local=src_l.reshape(rows, cols, e_cap),
        dst_local=dst_l.reshape(rows, cols, e_cap),
        e_counts=counts.reshape(rows, cols),
        m_input=g.m_input,
    )
