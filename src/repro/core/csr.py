"""Device-side graph containers and the 2D block partitioner (paper §2.6.2).

The 2D partition: an R x C processor grid; rank (i, j) owns adjacency block
``A_ij`` = edges (u -> v) with ``u`` in *column slice* j (width n/C) and
``v`` in *row slice* i (width n/R).  Vertex *ownership* (who stores
parent[v] / the frontier bit of v) follows the row-phase output layout:
the global vertex space is split into R*C chunks of size ``s = n/(R*C)``;
rank (i, j) owns chunk ``q = i*C + j``.

Static shapes: every per-rank edge block is padded to the same capacity
``e_cap`` with sentinel edges (src = n_c, dst = s_rows) that fall out of all
gathers/segment reductions — the TPU-native replacement for the paper's
"residuum" special cases (§7.2.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphgen.builder import CSRGraph


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Geometry of the R x C grid over n (padded) vertices."""

    n: int  # padded global vertex count
    n_orig: int  # pre-padding vertex count
    rows: int  # R
    cols: int  # C

    @property
    def n_r(self) -> int:  # row-slice width (vertices per grid row)
        return self.n // self.rows

    @property
    def n_c(self) -> int:  # column-slice width
        return self.n // self.cols

    @property
    def chunk(self) -> int:  # owned-chunk width s
        return self.n // (self.rows * self.cols)

    def owner(self, v: np.ndarray) -> np.ndarray:
        """Owned-chunk index q = v // s; rank (q // C, q % C)."""
        return v // self.chunk

    def transpose_perm(self) -> list[tuple[int, int]]:
        """ppermute pairs implementing the paper's TransposeVector (Alg. 2 l.4).

        Rank p = i*C + j owns chunk q = p.  The column phase needs rank
        (i, j) to hold chunk j*R + i (so the column-j all-gather assembles
        the contiguous column slice).  Returns (src_rank, dst_rank) pairs
        over the row-major linearized grid.
        """
        pairs = []
        r, c = self.rows, self.cols
        for i in range(r):
            for j in range(c):
                src = i * c + j  # owns chunk q = src
                q = src
                # chunk q is needed (in column phase) by rank (i', j') with
                # j'*R + i' = q  =>  j' = q // R, i' = q % R
                jp, ip = q // r, q % r
                dst = ip * c + jp
                pairs.append((src, dst))
        return pairs


@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    """2D-blocked edge arrays, shaped (R, C, e_cap) with local indices.

    ``src_local`` indexes into the column slice [0, n_c); ``dst_local`` into
    the row slice [0, n_r).  Padding edges use (n_c, n_r) sentinels.
    """

    part: Partition2D
    src_local: np.ndarray  # (R, C, e_cap) int32
    dst_local: np.ndarray  # (R, C, e_cap) int32
    e_counts: np.ndarray  # (R, C) int64 true edge counts per block
    m_input: int

    @property
    def e_cap(self) -> int:
        return int(self.src_local.shape[-1])


def _round_up(x: int, multiple: int) -> int:
    return -(-x // multiple) * multiple


def padded_geometry(n: int, rows: int, cols: int,
                    chunk_multiple: int = 1024) -> tuple[int, int]:
    """(padded n, chunk width s) that :func:`partition_2d` will produce for
    an ``n``-vertex graph — the single place the padding rule lives, so
    artifact writers (BENCH_comm.json's byte-model geometry) cannot drift
    from the replay's actual partition."""
    n_pad = _round_up(max(n, rows * cols), rows * cols * chunk_multiple)
    return n_pad, n_pad // (rows * cols)


def partition_2d(
    g: CSRGraph,
    rows: int,
    cols: int,
    chunk_multiple: int = 1024,
    e_cap_multiple: int = 1024,
) -> BlockedGraph:
    """Partition a CSR graph onto an R x C grid with static-capacity blocks.

    ``chunk_multiple`` keeps the owned-chunk width s a multiple of the
    bit-packing chunk (1024) so compressed exchanges stay lane-aligned.
    """
    n, _ = padded_geometry(g.n, rows, cols, chunk_multiple)
    part = Partition2D(n=n, n_orig=g.n, rows=rows, cols=cols)
    src, dst = g.src.astype(np.int64), g.dst.astype(np.int64)

    bj = src // part.n_c  # block column of each edge
    bi = dst // part.n_r  # block row
    block = bi * cols + bj
    order = np.argsort(block, kind="stable")
    src, dst, block = src[order], dst[order], block[order]
    counts = np.bincount(block, minlength=rows * cols)
    e_cap = _round_up(max(int(counts.max()), 1), e_cap_multiple)

    src_l = np.full((rows * cols, e_cap), part.n_c, dtype=np.int32)
    dst_l = np.full((rows * cols, e_cap), part.n_r, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in range(rows * cols):
        s0, cnt = starts[b], counts[b]
        if cnt == 0:
            continue
        i, j = divmod(b, cols)
        src_l[b, :cnt] = (src[s0 : s0 + cnt] - j * part.n_c).astype(np.int32)
        dst_l[b, :cnt] = (dst[s0 : s0 + cnt] - i * part.n_r).astype(np.int32)

    return BlockedGraph(
        part=part,
        src_local=src_l.reshape(rows, cols, e_cap),
        dst_local=dst_l.reshape(rows, cols, e_cap),
        e_counts=counts.reshape(rows, cols),
        m_input=g.m_input,
    )
