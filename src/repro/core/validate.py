"""Graph500 BFS-tree validation — the benchmark's 5 rules (paper Alg. 1 l.5).

Host-side (numpy) so it is independent of the JAX implementation under test.
The five rules, per the Graph500 specification:

  1. the BFS tree is a tree and does not contain cycles;
  2. each tree edge connects vertices whose BFS levels differ by exactly one;
  3. every edge in the input graph connects vertices whose levels differ by
     at most one, or both endpoints are unreached (same component check);
  4. the BFS tree spans exactly the connected component of the root;
  5. a node and its BFS parent are joined by an edge of the original graph.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphgen.builder import CSRGraph


@dataclasses.dataclass(frozen=True)
class ValidationResult:
    ok: bool
    failures: tuple[str, ...]
    n_reached: int
    n_tree_edges: int

    def __bool__(self) -> bool:
        return self.ok


def compute_levels(parent: np.ndarray, root: int, max_iter: int | None = None) -> np.ndarray:
    """Levels by pointer-jumping over parent links; -2 marks a cycle/overflow."""
    n = parent.shape[0]
    level = np.full(n, -1, dtype=np.int64)
    level[root] = 0
    reached = parent >= 0
    frontier = np.array([root])
    depth = 0
    max_iter = max_iter or n
    children = np.argsort(parent[reached], kind="stable")
    nodes = np.nonzero(reached)[0][children]
    parents_sorted = parent[nodes]
    while frontier.size and depth < max_iter:
        depth += 1
        lo = np.searchsorted(parents_sorted, frontier, side="left")
        hi = np.searchsorted(parents_sorted, frontier, side="right")
        nxt = np.concatenate([nodes[a:b] for a, b in zip(lo, hi)]) if frontier.size else frontier
        nxt = nxt[level[nxt] < 0]
        level[nxt] = depth
        frontier = nxt
    return level


def validate_bfs_tree(
    g: CSRGraph, parent: np.ndarray, root: int, level: np.ndarray | None = None
) -> ValidationResult:
    parent = np.asarray(parent, dtype=np.int64)[: g.n]
    n = g.n
    failures: list[str] = []

    reached = parent >= 0
    if not reached[root] or parent[root] != root:
        failures.append("rule1: root parent must be root itself")

    lv = compute_levels(parent, root)
    # Rule 1: no cycles — every reached vertex must get a finite level.
    stuck = reached & (lv < 0)
    if stuck.any():
        failures.append(f"rule1: {int(stuck.sum())} reached vertices not connected to root (cycle)")

    if level is not None:
        level = np.asarray(level, dtype=np.int64)[:n]
        mism = reached & (lv >= 0) & (level != lv)
        if mism.any():
            failures.append(f"levels: {int(mism.sum())} reported levels disagree with tree depth")

    # Rule 2 & 5: tree edges exist in graph and span exactly one level.
    tree_v = np.nonzero(reached & (np.arange(n) != root))[0]
    tree_u = parent[tree_v]
    if tree_v.size:
        # membership check via CSR binary search
        starts, ends = g.row_ptr[tree_u], g.row_ptr[tree_u + 1]
        exists = np.zeros(tree_v.size, dtype=bool)
        for k in range(tree_v.size):
            nbrs = g.col_idx[starts[k] : ends[k]]
            exists[k] = np.any(nbrs == tree_v[k])
        if not exists.all():
            failures.append(f"rule5: {int((~exists).sum())} tree edges missing from graph")
        dl = lv[tree_v] - lv[tree_u]
        bad = (lv[tree_v] >= 0) & (lv[tree_u] >= 0) & (dl != 1)
        if bad.any():
            failures.append(f"rule2: {int(bad.sum())} tree edges do not span exactly one level")

    # Rule 3: every graph edge spans <= 1 level, both-or-neither reached.
    eu, ev = g.src.astype(np.int64), g.dst.astype(np.int64)
    ru, rv = reached[eu], reached[ev]
    if (ru != rv).any():
        failures.append(f"rule4: {int((ru != rv).sum())} edges cross the reached boundary")
    both = ru & rv
    dl = np.abs(lv[eu[both]] - lv[ev[both]])
    if (dl > 1).any():
        failures.append(f"rule3: {int((dl > 1).sum())} graph edges span more than one level")

    # Rule 4: reached set == connected component of root (computed by ref BFS).
    comp = reference_bfs(g, root) >= 0
    if (reached != comp).any():
        failures.append(
            f"rule4: reached set differs from root component by {int((reached != comp).sum())}"
        )

    return ValidationResult(
        ok=not failures,
        failures=tuple(failures),
        n_reached=int(reached.sum()),
        n_tree_edges=int(tree_v.size),
    )


def reference_bfs(g: CSRGraph, root: int) -> np.ndarray:
    """Plain host BFS returning levels (-1 unreached) — the oracle."""
    level = np.full(g.n, -1, dtype=np.int64)
    level[root] = 0
    frontier = np.array([root], dtype=np.int64)
    d = 0
    while frontier.size:
        d += 1
        nbr_list = []
        for v in frontier:
            nbr_list.append(g.col_idx[g.row_ptr[v] : g.row_ptr[v + 1]])
        nbrs = np.unique(np.concatenate(nbr_list)) if nbr_list else np.array([], np.int64)
        nbrs = nbrs[level[nbrs] < 0]
        level[nbrs] = d
        frontier = nbrs
    return level


def reference_sssp(g: CSRGraph, root: int, max_weight: int = 31) -> np.ndarray:
    """Host Dijkstra over the hashed edge weights — the SSSP oracle.

    Weights come from :func:`repro.core.algebra.edge_weight` with ``xp=np``
    so the uint32 avalanche mix wraps identically to the in-graph version
    and the distance comparison is exact.  Unreached vertices hold
    ``repro.comm.formats.INF`` to match the device driver's encoding.
    """
    import heapq

    from repro.comm.formats import INF
    from repro.core.algebra import edge_weight

    dist = np.full(g.n, np.iinfo(np.int64).max, dtype=np.int64)
    dist[root] = 0
    pq = [(0, int(root))]
    while pq:
        du, u = heapq.heappop(pq)
        if du > dist[u]:
            continue
        nbrs = g.col_idx[g.row_ptr[u] : g.row_ptr[u + 1]]
        if nbrs.size == 0:
            continue
        w = edge_weight(
            np.full(nbrs.size, u, np.int64), nbrs.astype(np.int64),
            max_weight=max_weight, xp=np,
        ).astype(np.int64)
        for v, nd in zip(nbrs, du + w):
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (int(nd), int(v)))
    return np.where(dist == np.iinfo(np.int64).max, np.int64(INF), dist)


def reference_cc(g: CSRGraph) -> np.ndarray:
    """Union-find min-label components — the connected-components oracle.

    Returns, per vertex, the minimum vertex id of its component (the fixed
    point of min-label propagation, so it compares exactly against the
    ``cc`` algebra's value plane)."""
    parent = np.arange(g.n, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(g.src, g.dst):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    roots = np.array([find(i) for i in range(g.n)])
    # path-compressed roots ARE the min ids: union always keeps the smaller
    return roots


def reference_pagerank(
    g: CSRGraph, n: int | None = None, damping: float = 0.85,
    tol: float = 1e-4, max_iter: int = 500,
) -> np.ndarray:
    """Host power iteration — the PageRank oracle.

    Matches the ``pagerank`` algebra's conventions exactly: uniform init
    1/n over the (padded) vertex count ``n``, dangling mass NOT
    redistributed, termination on global L1 step-residual <= ``tol``.
    Pass the driver's padded ``part.n`` as ``n`` to compare elementwise.
    """
    n = g.n if n is None else n
    src = np.concatenate([g.src, g.dst]).astype(np.int64)
    dst = np.concatenate([g.dst, g.src]).astype(np.int64)
    deg = np.zeros(n, np.int64)
    np.add.at(deg, src, 1)
    v = np.full(n, 1.0 / n)
    for _ in range(max_iter):
        contrib = np.where(deg > 0, v / np.maximum(deg, 1), 0.0)
        nxt = np.full(n, (1.0 - damping) / n)
        np.add.at(nxt, dst, damping * contrib[src])
        done = np.abs(nxt - v).sum() <= tol
        v = nxt
        if done:
            break
    return v


def traversed_edges(g: CSRGraph, parent: np.ndarray) -> int:
    """TEPS numerator: input edges with both endpoints in the traversed
    component (Graph500 counts undirected input edges once)."""
    reached = np.asarray(parent)[: g.n] >= 0
    # m_input directed input edges were symmetrized; count input edges whose
    # endpoints are reached.  Approximation per spec: use input edge count
    # scaled by reached fraction of edges in the CSR.
    both = reached[g.src] & reached[g.dst]
    return int(both.sum()) // 2
