"""Batched-BFS centrality accumulation (Brandes-style tree dependencies).

The multi-source batch axis makes sampled-source centrality a one-sweep
post-pass over the driver output: each of the B parent/level planes is a
BFS tree, and summing per-source tree dependencies approximates
betweenness centrality the way sampled-source Brandes (Brandes 2001;
Bader/Madduri sampling) does.  Host-side numpy — the accumulation is a
single bottom-up sweep by level and runs on the already-gathered planes,
so it adds nothing to the device collective ledger.
"""

from __future__ import annotations

import numpy as np


def tree_betweenness(parents: np.ndarray, levels: np.ndarray, n: int) -> np.ndarray:
    """Brandes-style dependency accumulation over each source's BFS tree.

    ``parents``/``levels``: (B, n) batched BFS output (a single (n,) pair
    is promoted to B=1).  For each source plane, every vertex's dependency
    is the number of tree descendants below it (each shortest path in the
    tree contributes once); summing the per-source dependencies over the
    batch approximates betweenness centrality the way sampled-source
    Brandes does — the accumulation is a single bottom-up sweep by level
    over the batched parent planes.  Endpoint (root) contributions are
    excluded, matching the standard betweenness definition.
    """
    parents = np.atleast_2d(np.asarray(parents))[:, :n]
    levels = np.atleast_2d(np.asarray(levels))[:, :n]
    bc = np.zeros(n)
    for parent, level in zip(parents, levels):
        delta = np.zeros(n)
        order = np.argsort(level)[::-1]  # deepest levels first
        for v in order:
            if level[v] <= 0:  # unreached or the root itself
                continue
            delta[parent[v]] += 1.0 + delta[v]
        contrib = delta.copy()
        contrib[level == 0] = 0.0  # endpoints do not count
        bc += contrib
    return bc
