"""Frontier algebras: the (message, combine, update) triple as a registry axis.

"Compression and Sieve" (arXiv:1208.5542) frames the distributed frontier
exchange as moving *candidate updates*, not BFS parents specifically, and
the DGL ``gspmm`` idiom (op x reduce as parameters over one sparse kernel)
shows how a single engine serves many vertex programs.  This module makes
that generalization a fifth registry axis next to wire plans, traversal
policies, expansion backends and codecs: a :class:`FrontierAlgebra` owns

* the **message** each frontier source proposes along an edge
  (:meth:`FrontierAlgebra.edge_message`),
* the **combine** semiring operator that merges candidate messages — on
  the wire, in the butterfly's per-hop union-merge, and in the local
  segment reduce (:meth:`combine` / :meth:`segment_combine`),
* the **update / activation** rule deciding which vertices improved and
  what the next frontier is (:meth:`update` / :meth:`post_update`),
* the **termination** predicate (fixed point, empty frontier, or an
  L1-residual threshold carried by its own recorded all-reduce).

Everything on the wire stays int32.  Min-algebras (``bfs``, ``sssp``,
``cc``) use ``INF`` as the absent sentinel and ride the existing min-merge
collectives *verbatim* — the ``bfs`` instance is the current behavior,
extracted, and produces bit-identical results.  The sum-algebra
(``pagerank``) transports float32 values losslessly as their int32 bit
patterns (``enc``/``dec``); its absent sentinel is 0, whose bit pattern
decodes to 0.0, so sum-combines may simply decode, add and re-encode
without masking.

Four instances register here (resolved by name through
:func:`repro.comm.registry.algebra`):

``bfs``       min-parent: message = source id, payload IS the id (wires may
              localize/re-globalize it), activation = first touch.
``sssp``      min-plus over int32 distances with deterministic synthesized
              edge weights (:func:`edge_weight`); delta-stepping buckets
              ride a ``pending`` carry plus a recorded global ``pmin``
              window — the frontier is the pending set within ``delta`` of
              the global minimum tentative distance.
``cc``        min-label propagation from a dense initial frontier until no
              label changes (connected components).
``pagerank``  plus-times SpMV iteration: x = v/deg, combine = sum,
              v' = (1-d)/n + d * sum, terminated by a global L1-residual
              psum against ``tol``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm import registry as wire_registry
from repro.comm.butterfly import width_class
from repro.comm.formats import INF


def edge_weight(u, v, max_weight: int = 31, xp=jnp):
    """Deterministic symmetric integer weight of edge (u, v), in [1, max_weight].

    A uint32 avalanche mix over the sorted global endpoint pair —
    parameterized over the array namespace (``jnp`` in-graph, ``np`` for the
    host Dijkstra oracle) so both sides wrap identically mod 2**32 and the
    reference comparison is exact.  Symmetry (min/max ordering) matches the
    undirected edge lists both drivers traverse.
    """
    # atleast_1d: numpy scalars warn on uint32 wraparound, arrays wrap silently.
    a = xp.atleast_1d(xp.minimum(u, v)).astype(xp.uint32)
    b = xp.atleast_1d(xp.maximum(u, v)).astype(xp.uint32)
    h = a * xp.uint32(2654435761) ^ (b * xp.uint32(40503) + xp.uint32(2654435769))
    h = h ^ (h >> xp.uint32(16))
    w = (h % xp.uint32(max_weight)).astype(xp.int32) + 1
    return w.reshape(xp.broadcast_shapes(xp.shape(u), xp.shape(v)))


class _LocalExchange:
    """Engine facade for the single-device driver: group size 1, so the
    algebra's consensus collectives (psum / pmin) are identities."""

    def psum(self, x, **kw):
        return x

    def pmin(self, x, **kw):
        return x


LOCAL_EXCHANGE = _LocalExchange()


@dataclasses.dataclass(frozen=True)
class FrontierAlgebra:
    """One vertex program's semiring + activation rule (see module doc).

    Frozen and hashable so instances can ride jit static arguments.  All
    wire/carry planes are int32; ``enc``/``dec`` translate between the
    algebra's value domain and the int32 transport (identity for the
    integer min-algebras, float32 bit-casting for ``pagerank``).
    """

    name: str = ""
    reduce: str = "min"  # "min" | "sum": the combine operator's shape
    payload_is_id: bool = False  # wires may localize/re-globalize the payload
    needs_values: bool = False  # column phase must gather source values
    needs_deg: bool = False  # driver must materialize the owned degree slice
    starts_dense: bool = False  # initial frontier = every vertex
    uses_weights: bool = False  # messages consult edge_weight

    # --- transport ---------------------------------------------------------

    @property
    def empty(self) -> int:
        """Absent-candidate sentinel on the int32 wire."""
        return INF if self.reduce == "min" else 0

    def enc(self, x):
        return x

    def dec(self, x):
        return x

    def present(self, cand):
        """Mask of slots holding a real candidate (vs the sentinel)."""
        if self.reduce == "min":
            return cand < INF
        return cand != 0

    # --- semiring ----------------------------------------------------------

    def combine(self, a, b):
        if self.reduce == "min":
            return jnp.minimum(a, b)
        return self.enc(self.dec(a) + self.dec(b))

    def segment_combine(self, vals, segs, num_segments: int):
        """Per-destination reduce of candidate messages (local expansion).

        Sum needs no absent-mask: the sentinel 0 decodes to 0.0 and is the
        additive identity."""
        if self.reduce == "min":
            return jax.ops.segment_min(vals, segs, num_segments=num_segments)
        return self.enc(
            jax.ops.segment_sum(self.dec(vals), segs, num_segments=num_segments)
        )

    def row_payload_width(self, n_c: int, n: int) -> int:
        """Bit-packing class of the row wire's candidate payload."""
        return 32

    # --- messages ----------------------------------------------------------

    def source_values(self, value, deg):
        """Per-source message operand x from the owned value plane."""
        return value

    def edge_message(self, x_src, src_g, dst_g):
        """Candidate an edge proposes to its destination (encoded)."""
        return x_src

    # --- state -------------------------------------------------------------

    def init(self, hit, idx_global, roots32, n: int):
        """Initial (value, frontier) planes for the owned chunk."""
        raise NotImplementedError

    def init_aux(self, frontier) -> tuple:
        """Algebra-private level-loop carry (static pytree structure)."""
        return ()

    def update(self, value, cand, depth, n: int):
        """Fold reduced candidates into the value plane -> (value', new)."""
        raise NotImplementedError

    def pull_mask(self, value):
        """Destinations that accumulate candidates in pull expansion."""
        return jnp.ones(value.shape, bool)

    def post_update(
        self, ex, aux, value_prev, value, new, frontier_prev, plane_counts
    ):
        """Next (aux, frontier, counts, alive) after a level's update.

        ``ex`` exposes recorded ``psum``/``pmin`` over the whole grid (the
        termination exchange; :data:`LOCAL_EXCHANGE` on the single-device
        driver); ``plane_counts`` is the popcount kernel.  The algebra owns
        ALL of its termination consensus: every collective recorded here
        must feed ``alive`` or the next frontier, or XLA dead-code
        eliminates it and the CommStats/HLO reconciliation breaks.
        Default: fixed-point iteration — the frontier is what improved,
        and the program stops when nothing did.
        """
        counts = ex.psum(plane_counts(new), fmt="termination")
        return aux, new, counts, jnp.any(counts > 0)

    def finalize(self, value):
        """Decode the owned value plane into the algebra's output domain."""
        return value


@dataclasses.dataclass(frozen=True)
class BfsAlgebra(FrontierAlgebra):
    """Min-parent BFS: the pre-refactor driver's triple, extracted.

    The payload is the source id itself, so wires may strip it to a
    column-local offset and re-globalize on the receiver
    (``payload_is_id``), and no value gather is needed — membership bits
    carry the whole message."""

    name: str = "bfs"
    payload_is_id: bool = True

    def row_payload_width(self, n_c: int, n: int) -> int:
        return width_class(n_c)

    def init(self, hit, idx_global, roots32, n: int):
        value = jnp.where(hit, roots32[:, None], jnp.int32(-1))
        return value, hit

    def update(self, value, cand, depth, n: int):
        new = (cand < INF) & (value < 0)
        return jnp.where(new, cand, value), new

    def pull_mask(self, value):
        return value < 0


@dataclasses.dataclass(frozen=True)
class SsspAlgebra(FrontierAlgebra):
    """Min-plus single-source shortest paths with delta-stepping windows.

    Distances are int32 fixed point (INF = unreached); weights come from
    :func:`edge_weight` so the host Dijkstra oracle can re-derive them.
    The ``pending`` aux plane holds every vertex whose tentative distance
    improved but whose out-edges have not been relaxed at that distance;
    each level relaxes the pending set within ``delta`` of the global
    minimum tentative distance (a recorded ``pmin``) — ``delta = INF``
    degenerates to chaotic Bellman-Ford, small ``delta`` approaches
    Dijkstra's settled order.  Termination: no pending vertex anywhere
    (the window ``pmin`` comes back INF)."""

    name: str = "sssp"
    needs_values: bool = True
    uses_weights: bool = True
    delta: int = 31
    max_weight: int = 31

    def init(self, hit, idx_global, roots32, n: int):
        value = jnp.where(hit, jnp.int32(0), jnp.int32(INF))
        return value, hit

    def init_aux(self, frontier) -> tuple:
        return (frontier,)

    def edge_message(self, x_src, src_g, dst_g):
        w = edge_weight(src_g, dst_g, self.max_weight)
        return jnp.where(x_src >= INF - w, INF, x_src + w)

    def update(self, value, cand, depth, n: int):
        new = cand < value
        return jnp.minimum(value, cand), new

    def post_update(
        self, ex, aux, value_prev, value, new, frontier_prev, plane_counts
    ):
        (pending,) = aux
        pending = (pending & ~frontier_prev) | new
        local_min = jnp.min(
            jnp.where(pending, value, INF), axis=1
        )  # (B,) per-plane window floor
        m = ex.pmin(local_min, fmt="window")
        thresh = jnp.where(m >= INF - self.delta, INF, m + self.delta)
        frontier = pending & (value <= thresh[:, None])
        counts = ex.psum(plane_counts(frontier), fmt="frontier")
        # the vertex attaining the global window floor m is always in the
        # frontier, so counts>0 <=> m<INF — termination rides the counts
        # psum and both recorded collectives stay live in the HLO
        return (pending,), frontier, counts, jnp.any(counts > 0)


@dataclasses.dataclass(frozen=True)
class CcAlgebra(FrontierAlgebra):
    """Min-label propagation: every vertex starts labelled with its own
    global id and a dense frontier; labels flow along edges under min until
    a fixed point — each component converges to its minimum vertex id.
    Ignores the roots (batch planes compute the same labelling)."""

    name: str = "cc"
    needs_values: bool = True
    starts_dense: bool = True

    def row_payload_width(self, n_c: int, n: int) -> int:
        return width_class(n)  # labels are global vertex ids

    def init(self, hit, idx_global, roots32, n: int):
        b = hit.shape[0]
        value = jnp.broadcast_to(idx_global[None, :], (b, hit.shape[1]))
        return value.astype(jnp.int32), jnp.ones(hit.shape, bool)

    def update(self, value, cand, depth, n: int):
        new = cand < value
        return jnp.minimum(value, cand), new


@dataclasses.dataclass(frozen=True)
class PageRankAlgebra(FrontierAlgebra):
    """Plus-times PageRank: x = v/deg, v' = (1-d)/n + d * sum(x over
    in-edges), iterated to an L1 residual below ``tol`` (a recorded global
    psum).  float32 values ride the int32 wire as bit patterns — width-32
    bit-packing is the identity, so transport is lossless.  Vertices with
    no out-edges contribute nothing (dangling mass is not redistributed —
    the host oracle applies the same rule)."""

    name: str = "pagerank"
    reduce: str = "sum"
    needs_values: bool = True
    needs_deg: bool = True
    starts_dense: bool = True
    damping: float = 0.85
    tol: float = 1e-4

    def enc(self, x):
        return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)

    def dec(self, x):
        return jax.lax.bitcast_convert_type(x, jnp.float32)

    def init(self, hit, idx_global, roots32, n: int):
        b, s = hit.shape
        v0 = jnp.full((b, s), 1.0 / n, jnp.float32)
        return self.enc(v0), jnp.ones((b, s), bool)

    def source_values(self, value, deg):
        v = self.dec(value)
        x = jnp.where(deg[None, :] > 0, v / jnp.maximum(deg[None, :], 1), 0.0)
        return self.enc(x)

    def update(self, value, cand, depth, n: int):
        v = (1.0 - self.damping) / n + self.damping * self.dec(cand)
        value_new = self.enc(v)
        return value_new, value_new != value

    def post_update(
        self, ex, aux, value_prev, value, new, frontier_prev, plane_counts
    ):
        res_local = jnp.sum(
            jnp.abs(self.dec(value) - self.dec(value_prev)), axis=1
        )  # (B,) L1 residual share of the owned chunk
        res = ex.psum(res_local, fmt="residual")
        frontier = jnp.ones(value.shape, bool)
        # the frontier is dense every round, so its counts are a local
        # constant — only the residual consensus goes over the wire
        return aux, frontier, plane_counts(frontier), jnp.any(res > self.tol)

    def finalize(self, value):
        return self.dec(value)


ALGEBRAS = ("bfs", "sssp", "cc", "pagerank")

for _a in (BfsAlgebra(), SsspAlgebra(), CcAlgebra(), PageRankAlgebra()):
    wire_registry.register_algebra(_a)
del _a


def resolve(algebra) -> FrontierAlgebra:
    """Resolve by registry name, or pass a FrontierAlgebra instance through
    (parameterized instances — a custom ``delta`` or ``tol`` — need no
    registration)."""
    if isinstance(algebra, FrontierAlgebra):
        return algebra
    return wire_registry.algebra(algebra)
