"""The paper's primary contribution: compressed-communication distributed BFS.

* :mod:`repro.core.csr` — device-side graph containers + 2D block partitioner.
* :mod:`repro.core.bfs` — single-device level-synchronous BFS
  (``jax.lax.while_loop``; edge-centric SpMV formulation, paper Alg. 2).
* :mod:`repro.core.distributed_bfs` — 2D-partitioned BFS over ``shard_map``
  with compressed column/row collectives (paper Alg. 4).
* :mod:`repro.core.validate` — Graph500 5-rule BFS-tree validator.
"""
