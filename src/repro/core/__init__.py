"""The paper's primary contribution: compressed-communication distributed BFS.

* :mod:`repro.core.csr` — device-side graph containers + 2D block partitioner.
* :mod:`repro.core.traversal` — direction-optimizing traversal policies
  (top_down / bottom_up / direction_opt, paper §3.1) + the popcount
  density oracle; both BFS drivers dispatch their level loops through a
  policy resolved from :mod:`repro.comm.registry`.
* :mod:`repro.core.bfs` — single-device level-synchronous BFS
  (``jax.lax.while_loop``; edge-centric SpMV formulation, paper Alg. 2).
* :mod:`repro.core.distributed_bfs` — 2D-partitioned BFS over ``shard_map``
  with compressed column/row collectives (paper Alg. 4), policy x wire-plan
  configurable.
* :mod:`repro.core.validate` — Graph500 5-rule BFS-tree validator.
"""
