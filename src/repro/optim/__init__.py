"""Optimizers + distributed-optimization tricks.

* :mod:`repro.optim.adamw` — AdamW with the MiniCPM WSD
  (warmup-stable-decay) schedule.
* :mod:`repro.optim.grad_compress` — int8 error-feedback gradient
  compression for the data-parallel all-reduce (beyond-paper application of
  the paper's communication-compression insight).
"""
