"""AdamW + WSD (warmup-stable-decay) schedule, pure-pytree implementation.

WSD (MiniCPM, arXiv:2404.06395): linear warmup -> long constant plateau ->
short (10%) sharp decay.  The constant plateau is what makes mid-run
checkpoint branching cheap — relevant to the elastic-restart story in
train/checkpoint.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # WSD schedule
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1  # last 10% of steps decay
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def wsd_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Warmup-Stable-Decay learning-rate multiplier (MiniCPM §4)."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
    decay_t = (step - decay_start) / jnp.maximum(cfg.total_steps - decay_start, 1)
    decay = 1.0 - (1.0 - cfg.min_lr_frac) * jnp.clip(decay_t, 0.0, 1.0)
    mult = jnp.where(step < cfg.warmup_steps, warm, 1.0)
    return cfg.lr * jnp.where(step > decay_start, decay, mult)


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(grads: Any) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def apply(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> tuple[Any, OptState]:
    """One AdamW step with global-norm clipping and the WSD schedule."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = wsd_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v)
