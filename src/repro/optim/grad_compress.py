"""Int8 error-feedback gradient compression for data-parallel sync.

Beyond-paper: the paper compresses the *frontier* exchanged by BFS; the
same network-bound-collective insight applied to training is gradient
compression on the DP all-reduce.  Scheme (Karimireddy-style EF-SGD):

    e_t       <- residual carried from last step
    c_t       =  Q(g_t + e_t)            (int8 block quant, 128-value scales)
    e_{t+1}   =  (g_t + e_t) - deQ(c_t)  (local, exact)
    g_sync    =  allreduce(c_t) / world  (int8 payloads on the wire)

Error feedback makes the *accumulated* quantization error bounded, so SGD /
Adam converge at the uncompressed rate (up to constants).  Tested on a
quadratic in tests/test_optim.py.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import CommStats
from repro.comm import collectives as cc
from repro.kernels.quant import ref as quant


class EFState(NamedTuple):
    residual: Any  # same pytree as grads, fp32


def init(grads_shape: Any) -> EFState:
    return EFState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
    )


def _pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.size
    n_pad = -(-n // multiple) * multiple
    return jnp.pad(x.reshape(-1), (0, n_pad - n)), n


def compress_decompress(g: jax.Array) -> jax.Array:
    """Local quantize->dequantize round trip (what the wire sees)."""
    flat, n = _pad_to(g.astype(jnp.float32), quant.GROUP)
    q, s = quant.quantize(flat)
    return quant.dequantize(q, s)[:n].reshape(g.shape)


def ef_step(grads: Any, state: EFState) -> tuple[Any, EFState]:
    """Error-feedback compression (single-host form: the collective itself
    is applied by the caller via cc.allreduce_int8 inside shard_map)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        sent = compress_decompress(corrected)
        return sent.astype(g.dtype), corrected - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.residual)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        EFState(residual=treedef.unflatten([o[1] for o in out])),
    )


def dp_allreduce_int8(
    grads: Any,
    state: EFState,
    axis,
    group_size: int,
    stats: CommStats | None = None,
):
    """Full distributed EF int8 gradient mean over a mesh axis.

    For use inside shard_map over the DP axis: quantize (g + e), reduce via
    the comm plane's int8 all_to_all + all_gather, keep the residual
    locally.  ``stats``, if given, collects the per-leaf wire bytes.
    """

    def one(g, e, leaf: int):
        corrected = g.astype(jnp.float32) + e
        flat, n = _pad_to(corrected, group_size * quant.GROUP)
        reduced = (
            cc.allreduce_int8(
                flat, axis, group_size, stats=stats, phase=f"grad/allreduce[{leaf}]"
            )
            / group_size
        )
        sent = compress_decompress(corrected)
        return reduced[:n].reshape(g.shape).astype(g.dtype), corrected - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.residual)
    out = [one(g, e, k) for k, (g, e) in enumerate(zip(flat_g, flat_e))]
    return (
        treedef.unflatten([o[0] for o in out]),
        EFState(residual=treedef.unflatten([o[1] for o in out])),
    )
