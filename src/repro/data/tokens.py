"""Synthetic LM token pipeline: deterministic, double-buffered.

Tokens are a structured synthetic language (Zipf unigrams + short-range
copy structure) so a small model's loss visibly decreases — enough signal
to validate the end-to-end training driver without external datasets.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_alpha: float = 1.1
    copy_prob: float = 0.3  # prob. a token copies the token 4 back


def batch_at(cfg: TokenPipelineConfig, step: int) -> dict[str, np.ndarray]:
    """The batch for a given step — pure function of (cfg, step)."""
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    ranks = np.arange(1, min(cfg.vocab, 1 << 14) + 1, dtype=np.float64)
    p = ranks**-cfg.zipf_alpha
    p /= p.sum()
    toks = rng.choice(len(ranks), size=(cfg.batch, cfg.seq_len), p=p).astype(np.int32)
    copy = rng.random((cfg.batch, cfg.seq_len)) < cfg.copy_prob
    copy[:, :4] = False
    rolled = np.roll(toks, 4, axis=1)
    toks = np.where(copy, rolled, toks)
    return {"tokens": toks % cfg.vocab}


class DoubleBufferedLoader:
    """Background-thread prefetch of the next batch (paper §6.5.2's
    comm/compute overlap, applied to the host input pipeline)."""

    def __init__(self, cfg: TokenPipelineConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                self._q.put(batch_at(self.cfg, step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
