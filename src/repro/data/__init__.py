"""Deterministic synthetic data pipelines.

Every pipeline is a pure function of (config, step) so that checkpoint
restart replays the exact same stream — the determinism half of the fault-
tolerance story (train/checkpoint.py holds the other half).
"""
