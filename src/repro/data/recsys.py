"""Zipf categorical click-log generator (Criteo-like synthetic stream)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClickLogConfig:
    table_sizes: tuple[int, ...]
    batch: int
    seed: int = 0
    zipf_alpha: float = 1.05


def batch_at(cfg: ClickLogConfig, step: int) -> dict[str, np.ndarray]:
    """Deterministic (cfg, step) -> {ids (B, F), labels (B,)}.

    Ids are Zipf-skewed (hot rows dominate, like real CTR traffic) via an
    inverse-CDF power transform — no giant probability vectors needed.
    """
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    f = len(cfg.table_sizes)
    u = rng.random((cfg.batch, f))
    skew = u ** (cfg.zipf_alpha + 1.0)  # mass near 0 = hot rows
    sizes = np.asarray(cfg.table_sizes)
    ids = np.minimum((skew * sizes).astype(np.int64), sizes - 1)
    # labels correlate with a hash of the first few fields (learnable signal)
    h = (ids[:, :4].sum(axis=1) % 7) < 3
    noise = rng.random(cfg.batch) < 0.1
    labels = (h ^ noise).astype(np.float32)
    return {"ids": ids.astype(np.int32), "labels": labels}
