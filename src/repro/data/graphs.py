"""Graph batch pipelines: shape-spec synthetic graphs + neighbor sampler.

The four GNN shape cells need different generators:

* ``full_graph_sm`` / ``ogb_products`` — one static graph with the spec's
  (n, m, d_feat); RMAT connectivity (power-law, like the real datasets).
* ``minibatch_lg`` — layered neighbor sampling (GraphSAGE fanout 15-10) out
  of a large graph: a REAL sampler over CSR, not a stub.
* ``molecule`` — batched small graphs (block-diagonal union with offsets).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphgen import builder, kronecker


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Host-side static-shape graph batch (padding edges: src=dst=n)."""

    nf: np.ndarray  # (n, d) float32
    src: np.ndarray  # (m,) int32
    dst: np.ndarray  # (m,) int32
    pos: np.ndarray | None  # (n, 3)
    targets: np.ndarray  # (n,) int or (n, d_out) float
    mask: np.ndarray | None = None  # (n,) valid-node mask


def synthetic_graph(
    n_nodes: int, n_edges: int, d_feat: int, seed: int = 0, n_classes: int = 16
) -> GraphBatch:
    """RMAT-connectivity graph with the exact (n, m) of a shape spec."""
    rng = np.random.default_rng(seed)
    scale = max(int(np.ceil(np.log2(n_nodes))), 1)
    ef = max(n_edges // (1 << scale), 1)
    e = kronecker.rmat_edges(scale, edgefactor=ef, seed=seed)
    e = e[e.max(axis=1) < n_nodes]
    if e.shape[0] >= n_edges:
        e = e[:n_edges]
    else:  # top up with uniform edges to hit the spec's m exactly
        extra = rng.integers(0, n_nodes, size=(n_edges - e.shape[0], 2))
        e = np.concatenate([e, extra])
    return GraphBatch(
        nf=rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        src=e[:, 0].astype(np.int32),
        dst=e[:, 1].astype(np.int32),
        pos=rng.normal(size=(n_nodes, 3)).astype(np.float32),
        targets=rng.integers(0, n_classes, n_nodes).astype(np.int32),
    )


# ---------------------------------------------------------------------------
# layered neighbor sampler (minibatch_lg: batch_nodes=1024, fanout 15-10)
# ---------------------------------------------------------------------------


class NeighborSampler:
    """Uniform fanout sampling over a CSR graph (GraphSAGE-style).

    Produces static-shape blocks: seeds (B,), hop-1 (B*f1,), hop-2
    (B*f1*f2,) with edges between consecutive layers.  Sampling with
    replacement keeps shapes static (standard for TPU pipelines).
    """

    def __init__(self, g: builder.CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.g = g
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (nodes (N,), src (M,), dst (M,)) with *local* indices.

        nodes concatenates [seeds, hop1, hop2, ...]; every sampled edge
        points from a hop-k+1 node to its hop-k parent (message direction).
        """
        g = self.g
        layers = [seeds.astype(np.int64)]
        src_l, dst_l = [], []
        base = 0
        for f in self.fanouts:
            frontier = layers[-1]
            deg = (g.row_ptr[frontier + 1] - g.row_ptr[frontier]).astype(np.int64)
            # sample f neighbors with replacement (isolated nodes self-loop)
            offs = self.rng.integers(0, np.maximum(deg, 1)[:, None], size=(frontier.size, f))
            nbr_idx = g.row_ptr[frontier][:, None] + offs
            nbrs = np.where(
                deg[:, None] > 0, g.col_idx[np.minimum(nbr_idx, g.m - 1)], frontier[:, None]
            )
            next_base = base + frontier.size
            src_l.append(next_base + np.arange(frontier.size * f))
            dst_l.append(np.repeat(base + np.arange(frontier.size), f))
            layers.append(nbrs.reshape(-1))
            base = next_base
        nodes = np.concatenate(layers)
        return (
            nodes,
            np.concatenate(src_l).astype(np.int32),
            np.concatenate(dst_l).astype(np.int32),
        )

    def batch(self, seeds: np.ndarray, d_feat: int, feat_seed: int = 0) -> GraphBatch:
        nodes, src, dst = self.sample(seeds)
        rng = np.random.default_rng(feat_seed)
        nf = rng.normal(size=(nodes.size, d_feat)).astype(np.float32)
        mask = np.zeros(nodes.size, np.float32)
        mask[: seeds.size] = 1.0  # loss only on seed nodes
        return GraphBatch(
            nf=nf,
            src=src,
            dst=dst,
            pos=rng.normal(size=(nodes.size, 3)).astype(np.float32),
            targets=rng.integers(0, 16, nodes.size).astype(np.int32),
            mask=mask,
        )


def sampled_shape(batch_nodes: int, fanouts: tuple[int, ...]) -> tuple[int, int]:
    """(n_nodes, n_edges) of a sampled block — static, from the fanout spec."""
    n, m, layer = batch_nodes, 0, batch_nodes
    for f in fanouts:
        m += layer * f
        layer *= f
        n += layer
    return n, m


def molecule_batch(
    n_mols: int, nodes_per: int, edges_per: int, d_feat: int, seed: int = 0
) -> GraphBatch:
    """Block-diagonal union of small molecular graphs (batched-small-graphs)."""
    rng = np.random.default_rng(seed)
    n = n_mols * nodes_per
    src = np.concatenate(
        [k * nodes_per + rng.integers(0, nodes_per, edges_per) for k in range(n_mols)]
    )
    dst = np.concatenate(
        [k * nodes_per + rng.integers(0, nodes_per, edges_per) for k in range(n_mols)]
    )
    return GraphBatch(
        nf=rng.normal(size=(n, d_feat)).astype(np.float32),
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        pos=rng.normal(size=(n, 3)).astype(np.float32) * 2.0,
        targets=rng.normal(size=(n, 1)).astype(np.float32),
    )
