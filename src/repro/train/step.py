"""Train-step factories.

Two flavors:

* :func:`make_train_step` — GSPMD path: one jit'd step for arbitrarily
  sharded params (FSDP x TP); gradients are synced implicitly by the
  partitioner.  Used by the big assigned-architecture configs.
* :func:`make_dp_train_step` — explicit data-parallel path via shard_map
  with the **compressed gradient all-reduce** (int8 + error feedback) on
  the wire — the paper's communication-compression insight applied to
  training (beyond-paper; see optim/grad_compress.py).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.optim import adamw, grad_compress


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    ef: grad_compress.EFState | None = None


def init_state(params: Any, with_ef: bool = False) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw.init(params),
        ef=grad_compress.init(params) if with_ef else None,
    )


def make_train_step(
    loss_fn: Callable[[Any, Any], jax.Array], opt_cfg: adamw.AdamWConfig
):
    """GSPMD train step: state/batch sharding comes from in_shardings."""

    def step(state: TrainState, batch) -> tuple[TrainState, dict[str, jax.Array]]:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        params, opt = adamw.apply(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, "grad_norm": adamw.global_norm(grads), "step": opt.step}
        return TrainState(params=params, opt=opt, ef=state.ef), metrics

    return step


def make_dp_train_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    opt_cfg: adamw.AdamWConfig,
    mesh: Mesh,
    dp_axis: str = "data",
    compress: bool = True,
):
    """Pure-DP step over shard_map: params replicated, batch sharded over
    ``dp_axis``, gradient mean over the wire as int8 + error feedback
    (or plain psum when ``compress=False``)."""
    dp = mesh.shape[dp_axis]

    def local_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        if compress:
            grads, ef = grad_compress.dp_allreduce_int8(grads, state.ef, dp_axis, dp)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axis), grads)
            ef = state.ef
        loss = jax.lax.pmean(loss, dp_axis)
        params, opt = adamw.apply(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, "grad_norm": adamw.global_norm(grads)}
        return TrainState(params=params, opt=opt, ef=ef), metrics

    rep = P()
    batch_spec = P(dp_axis)
    mapped = compat.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(rep, batch_spec),
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(mapped)
