"""Training runtime: step factories, checkpointing, fault tolerance."""
