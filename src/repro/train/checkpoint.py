"""Sharded checkpointing with atomic manifests and elastic restore.

Layout per step::

    <dir>/step_000123/
        host_0000.npz     # this host's addressable shards (flat leaf list)
        MANIFEST.json     # step, tree structure, leaf shapes/dtypes, status

Properties:

* **atomic**: data is written into ``step_N.tmp/`` and renamed at the end;
  a crash mid-write never corrupts the latest-complete pointer.
* **async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes in a background thread — the training loop never waits
  on disk (paper §6.5.2's overlap idea applied to I/O).
* **elastic**: restore returns host numpy arrays; ``restore_sharded`` then
  ``device_put``s onto *any* mesh/sharding — the restoring job may use a
  different device count than the saving job (reshard-on-restore).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(state: Any, step: int, ckpt_dir: str) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    leaves, treedef = _flatten_with_paths(state)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "host_0000.npz"), *host_leaves)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(x.shape) for x in host_leaves],
        "dtypes": [str(x.dtype) for x in host_leaves],
        "status": "complete",
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a complete manifest (ignores torn .tmp dirs)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        mpath = os.path.join(ckpt_dir, name, "MANIFEST.json")
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("status") == "complete":
                steps.append(m["step"])
        except (OSError, json.JSONDecodeError):
            continue
    return max(steps) if steps else None


def restore(like: Any, step: int, ckpt_dir: str) -> Any:
    """Restore into the structure of ``like`` (host numpy leaves)."""
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with np.load(os.path.join(path, "host_0000.npz")) as z:
        host_leaves = [z[k] for k in z.files]
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(host_leaves), "checkpoint/state structure mismatch"
    for l, h in zip(leaves, host_leaves):
        assert tuple(l.shape) == tuple(h.shape), (l.shape, h.shape)
    return jax.tree.unflatten(treedef, host_leaves)


def restore_sharded(like: Any, step: int, ckpt_dir: str, shardings: Any) -> Any:
    """Elastic restore: place host arrays onto a (possibly different) mesh."""
    host_state = restore(like, step, ckpt_dir)
    return jax.tree.map(
        lambda h, s: jax.device_put(h, s), host_state, shardings
    )


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; at most one pending
    write (a newer snapshot supersedes a queued one)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._lock = threading.Lock()
        self._pending: tuple[Any, int] | None = None
        self._thread: threading.Thread | None = None
        self.written: list[int] = []

    def submit(self, state: Any, step: int) -> None:
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        with self._lock:
            self._pending = (snapshot, step)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self) -> None:
        while True:
            with self._lock:
                if self._pending is None:
                    return
                snapshot, step = self._pending
                self._pending = None
            save(snapshot, step, self.ckpt_dir)
            self.written.append(step)

    def wait(self) -> None:
        t = self._thread
        if t is not None:
            t.join()
