"""Fault tolerance: step watchdog, straggler detection, restart policy.

At thousands of nodes, *something* is always failing.  The runtime pieces:

* deterministic data (data/*: batch = f(config, step)) + atomic checkpoints
  (train/checkpoint.py) give **restart-exact** recovery;
* :class:`StepWatchdog` flags hung steps and straggler steps (> k x rolling
  median) — the trigger for preemptive checkpoint + reschedule;
* :func:`resume_or_init` is the single entry point the launcher uses: it
  either restores the newest complete checkpoint or initializes fresh.

Straggler *mitigation* on the collective path is structural: the bucketed
compressed exchanges (comm/collectives.py) shrink the operand of the
slowest link, which is where tail latency lives (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.train import checkpoint


@dataclasses.dataclass
class StepWatchdog:
    """Rolling-median step timer with straggler / hang classification."""

    straggler_factor: float = 3.0
    hang_timeout_s: float = 300.0
    window: int = 32

    def __post_init__(self):
        self._times: list[float] = []
        self._t0: float | None = None
        self.stragglers: list[int] = []
        self.step_idx = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> str:
        """Record one step; returns 'ok' | 'straggler'."""
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        verdict = "ok"
        if len(self._times) >= 5:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.straggler_factor * med:
                verdict = "straggler"
                self.stragglers.append(self.step_idx)
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        self.step_idx += 1
        return verdict

    def is_hung(self) -> bool:
        return self._t0 is not None and (time.monotonic() - self._t0) > self.hang_timeout_s


def resume_or_init(
    init_fn: Callable[[], Any], ckpt_dir: str, shardings: Any | None = None
) -> tuple[Any, int]:
    """Restore the newest complete checkpoint, or initialize fresh.

    Returns (state, start_step).  With ``shardings`` given, restore is
    elastic (arrays placed on the current mesh regardless of the saver's)."""
    step = checkpoint.latest_step(ckpt_dir)
    if step is None:
        return init_fn(), 0
    like = init_fn()  # structure donor (shapes/dtypes/tree)
    if shardings is not None:
        state = checkpoint.restore_sharded(like, step, ckpt_dir, shardings)
    else:
        state = checkpoint.restore(like, step, ckpt_dir)
    return state, step + 1
