"""Cell builders: (arch x shape x mesh) -> lowerable function + specs + meta.

A *cell* is one entry of the 40-cell dry-run grid (plus the paper's own
graph500 cells).  ``build_cell`` returns everything ``dryrun.py`` needs:

* ``fn``            — the jit-able step (train_step / prefill / decode /
                      serve / retrieval / bfs),
* ``args``          — ShapeDtypeStruct pytree (no allocation, ever),
* ``in_shardings``  — NamedSharding pytree for the production mesh,
* ``meta``          — analytic MODEL_FLOPS, param counts, loop multiplier
                      for the roofline HLO scaling (scan bodies count once).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import common as cfgs
from repro.core import distributed_bfs as dbfs
from repro.core.csr import Partition2D
from repro.launch import mesh as meshlib
from repro.models import gnn, gnn_dist, recsys
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import step as tstep


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable | None = None
    args: tuple = ()
    in_shardings: Any = None
    meta: dict = dataclasses.field(default_factory=dict)
    skip_reason: str = ""

    @property
    def cell_id(self) -> str:
        return f"{self.arch_id}/{self.shape_name}"


def _shard(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda sp: None if sp is None else NamedSharding(mesh, sp),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# analytic FLOPs (MODEL_FLOPS for the roofline: useful work, global)
# ---------------------------------------------------------------------------


def lm_train_flops(cfg: tfm.TransformerConfig, batch: int, seq: int) -> float:
    tokens = batch * seq
    dense = 6.0 * cfg.n_active_params() * tokens
    attn_fwd = batch * cfg.n_layers * cfg.n_heads * seq * seq * (
        cfg.qk_head_dim + (cfg.v_head_dim if cfg.use_mla else cfg.head_dim)
    )
    return dense + 3.0 * attn_fwd


def lm_prefill_flops(cfg: tfm.TransformerConfig, batch: int, seq: int) -> float:
    tokens = batch * seq
    dense = 2.0 * cfg.n_active_params() * tokens
    attn = batch * cfg.n_layers * cfg.n_heads * seq * seq * (
        cfg.qk_head_dim + (cfg.v_head_dim if cfg.use_mla else cfg.head_dim)
    )
    return dense + attn


def lm_decode_flops(cfg: tfm.TransformerConfig, batch: int, seq: int) -> float:
    dense = 2.0 * cfg.n_active_params() * batch
    if cfg.use_mla:  # absorbed decode reads the latent cache
        attn = 2.0 * batch * cfg.n_layers * cfg.n_heads * seq * (
            cfg.kv_lora_rank + cfg.qk_rope_dim
        ) * 2
    else:
        attn = 2.0 * batch * cfg.n_layers * cfg.n_heads * seq * 2 * cfg.head_dim
    return dense + attn


def _mlp_flops(dims: tuple[int, ...]) -> float:
    return 2.0 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def gnn_flops(cfg, n: int, m: int, d_in: int) -> float:
    if isinstance(cfg, gnn.GraphCastConfig):
        d = cfg.d_hidden
        per_layer = m * _mlp_flops((3 * d, d, d)) + n * _mlp_flops((2 * d, d, d))
        return cfg.n_layers * per_layer + n * (
            _mlp_flops((d_in, d, d)) + _mlp_flops((d, d, cfg.d_out))
        )
    if isinstance(cfg, gnn.GATConfig):
        f = 0.0
        d_prev = d_in
        for i in range(cfg.n_layers):
            last = i == cfg.n_layers - 1
            heads = 1 if last else cfg.n_heads
            d_o = cfg.d_out if last else cfg.d_hidden
            f += 2.0 * n * heads * d_prev * d_o + 6.0 * m * heads * d_o
            d_prev = heads * d_o
        return f
    if isinstance(cfg, gnn.EGNNConfig):
        d = cfg.d_hidden
        per_layer = m * (_mlp_flops((2 * d + 1, d, d)) + _mlp_flops((d, d, 1))) + n * _mlp_flops(
            (2 * d, d, d)
        )
        return cfg.n_layers * per_layer + n * (
            _mlp_flops((cfg.d_in, d)) + _mlp_flops((d, cfg.d_out))
        )
    if isinstance(cfg, gnn.NequIPConfig):
        c = cfg.d_hidden
        # radial MLP + tensor-product paths (13c floats/node state)
        per_edge = _mlp_flops((cfg.n_rbf, c, 3 * c)) + 2.0 * 13 * c * 9
        per_node = 2.0 * 3 * c * c + _mlp_flops((c, 2 * c))
        return cfg.n_layers * (m * per_edge + n * per_node)
    raise TypeError(type(cfg))


def recsys_flops(cfg: recsys.AutoIntConfig, batch: int) -> float:
    f, d, da, h = cfg.n_sparse, cfg.embed_dim, cfg.d_attn, cfg.n_heads
    flops = 0.0
    d_prev = d
    for _ in range(cfg.n_attn_layers):
        flops += batch * (
            3 * 2 * f * h * d_prev * da + 2 * 2 * h * f * f * da + 2 * f * d_prev * h * da
        )
        d_prev = h * da
    dims = (f * d_prev,) + cfg.mlp_dims + (1,)
    flops += batch * _mlp_flops(dims)
    return flops


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(
    spec: cfgs.ArchSpec, shape: cfgs.ShapeSpec, mesh: Mesh, variant: str = "baseline"
) -> Cell:
    cfg: tfm.TransformerConfig = spec.model_config()
    fsdp = meshlib.fsdp_axes(mesh)
    chips = mesh.size
    # --- §Perf variants (EXPERIMENTS.md) -----------------------------------
    if "bf16" in variant:  # bf16 param storage (fp32 Adam moments kept)
        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    if "moegroup256" in variant and cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_group=256)
    if "noremat" in variant:
        cfg = dataclasses.replace(cfg, remat=False)
    if "dotsave" in variant:  # the ORIGINAL (pathological) remat policy
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    if "moepin" in variant and cfg.is_moe:  # pin MoE dispatch shardings
        cfg = dataclasses.replace(cfg, moe_dp_axes=fsdp, moe_tp_axis="model")
    if "experttp" in variant and cfg.is_moe:  # resident expert weights
        cfg = dataclasses.replace(cfg, expert_shard="ff")
    serve_fsdp = () if "tpserve" in variant else fsdp  # TP-only serving params
    # -----------------------------------------------------------------------
    p_specs = tfm.param_specs(cfg, fsdp=fsdp, tp="model")
    batch = shape.params["global_batch"]
    seq = shape.params["seq_len"]
    dp = fsdp if len(fsdp) > 1 else fsdp[0]

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        step_fn = tstep.make_train_step(functools.partial(tfm.loss_fn, cfg), opt_cfg)
        state = jax.eval_shape(
            lambda: tstep.init_state(tfm.init_params(cfg, jax.random.PRNGKey(0)))
        )
        state_specs = tstep.TrainState(
            params=p_specs,
            opt=adamw.OptState(step=P(), m=p_specs, v=p_specs),
            ef=None,
        )
        batch_sds = {"tokens": _sds((batch, seq), jnp.int32)}
        batch_specs = {"tokens": P(dp, None)}
        return Cell(
            spec.arch_id, shape.name, "train",
            fn=step_fn,
            args=(state, batch_sds),
            in_shardings=(_shard(mesh, state_specs), _shard(mesh, batch_specs)),
            meta=dict(
                model_flops=lm_train_flops(cfg, batch, seq),
                n_params=cfg.n_params(),
                n_active=cfg.n_active_params(),
                loop_mult=float(cfg.n_layers),
            ),
        )

    params = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    if shape.kind in ("prefill", "decode") and not serve_fsdp:
        # serving layout: weights fully TP-sharded + replicated over data —
        # no per-step FSDP weight all-gather on the latency path
        p_specs = jax.tree.map(
            lambda sp: P(*[("model" if e == "model" else None) for e in sp]),
            p_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
    if shape.kind == "prefill":
        fn = functools.partial(tfm.prefill, cfg)
        toks = _sds((batch, seq), jnp.int32)
        return Cell(
            spec.arch_id, shape.name, "prefill",
            fn=fn,
            args=(params, toks),
            in_shardings=(_shard(mesh, p_specs), NamedSharding(mesh, P(dp, None))),
            meta=dict(
                model_flops=lm_prefill_flops(cfg, batch, seq),
                n_params=cfg.n_params(),
                loop_mult=float(cfg.n_layers),
            ),
        )

    if shape.kind == "decode":
        fn = functools.partial(tfm.decode_step, cfg)
        cache = _sds((cfg.n_layers, batch, seq, cfg.cache_width), cfg.compute_dtype)
        toks = _sds((batch,), jnp.int32)
        pos = _sds((batch,), jnp.int32)
        cache_sh = NamedSharding(mesh, tfm.cache_spec(fsdp=fsdp, tp="model"))
        return Cell(
            spec.arch_id, shape.name, "decode",
            fn=fn,
            args=(params, cache, toks, pos),
            in_shardings=(
                _shard(mesh, p_specs),
                cache_sh,
                NamedSharding(mesh, P(dp)),
                NamedSharding(mesh, P(dp)),
            ),
            meta=dict(
                model_flops=lm_decode_flops(cfg, batch, seq),
                n_params=cfg.n_params(),
                cache_bytes=cfg.n_layers * batch * seq * cfg.cache_width
                * np.dtype(cfg.compute_dtype).itemsize,
                loop_mult=float(cfg.n_layers),
            ),
        )
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_model_cfg(spec: cfgs.ArchSpec, d_in: int, d_out: int):
    return spec.model_config(d_in=d_in, d_out=d_out)


def _gnn_cell(spec: cfgs.ArchSpec, shape: cfgs.ShapeSpec, mesh: Mesh) -> Cell:
    p = shape.params
    dist = p["dist"]
    fsdp = meshlib.fsdp_axes(mesh)
    dp = fsdp if len(fsdp) > 1 else fsdp[0]

    if dist == "2d":
        return _gnn_2d_cell(spec, shape, mesh)

    if dist == "batched":
        n = p["n_nodes"] * p["batch"]
        m = p["n_edges"] * p["batch"]
    elif dist == "sampled":
        from repro.data.graphs import sampled_shape

        n, m = sampled_shape(p["batch_nodes"], p["fanout"])
    else:
        n, m = p["n_nodes"], p["n_edges"]
    d_in, n_classes = p["d_feat"], p["n_classes"]
    cfg = _gnn_model_cfg(spec, d_in, n_classes)
    if isinstance(cfg, gnn.GraphCastConfig):
        cfg = dataclasses.replace(cfg, edge_state=dist not in ("2d",))

    params = jax.eval_shape(lambda: gnn.init(cfg, jax.random.PRNGKey(0)))
    opt_cfg = adamw.AdamWConfig()
    loss = functools.partial(gnn.loss_fn, cfg)
    step_fn = tstep.make_train_step(loss, opt_cfg)
    state = jax.eval_shape(lambda: tstep.init_state(gnn.init(cfg, jax.random.PRNGKey(0))))
    rep = jax.tree.map(lambda _: P(), params)
    state_specs = tstep.TrainState(
        params=rep, opt=adamw.OptState(step=P(), m=rep, v=rep), ef=None
    )
    # nodes/edges sharded over the data axes when divisible, else replicated
    dp_prod = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_prod *= mesh.shape[a]
    node_ax = dp if n % dp_prod == 0 else None
    edge_ax = dp if m % dp_prod == 0 else None

    graph_sds = gnn.Graph(
        nf=_sds((n, d_in), jnp.float32),
        src=_sds((m,), jnp.int32),
        dst=_sds((m,), jnp.int32),
        pos=_sds((n, 3), jnp.float32),
    )
    graph_specs = gnn.Graph(
        nf=P(node_ax, None), src=P(edge_ax), dst=P(edge_ax), pos=P(node_ax, None)
    )
    batch_sds = {"graph": graph_sds, "targets": _sds((n,), jnp.int32)}
    batch_specs = {"graph": graph_specs, "targets": P(node_ax)}
    return Cell(
        spec.arch_id, shape.name, "graph_train",
        fn=step_fn,
        args=(state, batch_sds),
        in_shardings=(_shard(mesh, state_specs), _shard(mesh, batch_specs)),
        meta=dict(
            model_flops=3.0 * gnn_flops(cfg, n, m, d_in),
            n_params=sum(x.size for x in jax.tree.leaves(params)),
            loop_mult=1.0,
            n_nodes=n,
            n_edges=m,
        ),
    )


def _gnn_2d_cell(spec: cfgs.ArchSpec, shape: cfgs.ShapeSpec, mesh: Mesh) -> Cell:
    p = shape.params
    rows, cols = meshlib.grid_rows_cols(mesh)
    n_pad = _round_up(p["n_nodes"], rows * cols * 1024)
    part = Partition2D(n=n_pad, n_orig=p["n_nodes"], rows=rows, cols=cols)
    e_cap = _round_up(2 * p["n_edges"] // (rows * cols), 1024)
    d_in, n_classes = p["d_feat"], p["n_classes"]
    cfg = _gnn_model_cfg(spec, d_in, n_classes)
    if isinstance(cfg, gnn.GraphCastConfig):
        cfg = dataclasses.replace(cfg, edge_state=False)
    dcfg = gnn_dist.Dist2DConfig(
        row_axes=meshlib.fsdp_axes(mesh),
        col_axis="model",
        quantize_payload=spec.arch_id in ("graphcast", "gat-cora"),
    )
    step_fn, in_specs = gnn_dist.build_2d_train_step(mesh, cfg, part, e_cap, dcfg)
    params = jax.eval_shape(lambda: gnn.init(cfg, jax.random.PRNGKey(0)))
    s = part.chunk
    ax_sizes = tuple(mesh.shape[a] for a in dcfg.all_axes)
    args = (
        params,
        _sds(ax_sizes + (s, d_in), jnp.float32),
        _sds(ax_sizes + (s, 3), jnp.float32),
        _sds(ax_sizes + (e_cap,), jnp.int32),
        _sds(ax_sizes + (e_cap,), jnp.int32),
        _sds(ax_sizes + (s,), jnp.int32),
    )
    # params replicated; data arrays owner-chunk / block sharded
    in_sh = (_shard(mesh, jax.tree.map(lambda _: P(), params)),) + tuple(
        NamedSharding(mesh, sp) for sp in in_specs[1:]
    )
    return Cell(
        spec.arch_id, shape.name, "graph_train_2d",
        fn=step_fn,
        args=args,
        in_shardings=in_sh,
        meta=dict(
            model_flops=3.0 * gnn_flops(cfg, p["n_nodes"], p["n_edges"], d_in),
            n_params=sum(x.size for x in jax.tree.leaves(params)),
            loop_mult=1.0,
            n_nodes=p["n_nodes"],
            n_edges=p["n_edges"],
            e_cap=e_cap,
        ),
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------


def _recsys_cell(
    spec: cfgs.ArchSpec, shape: cfgs.ShapeSpec, mesh: Mesh, variant: str = "baseline"
) -> Cell:
    cfg: recsys.AutoIntConfig = spec.model_config()
    fsdp = meshlib.fsdp_axes(mesh)
    all_axes = fsdp + ("model",)
    p_specs = recsys.param_specs(cfg, fsdp=fsdp, tp="model")
    if "int8table" in variant:
        cfg = dataclasses.replace(cfg, table_quant=True)
        p_specs = recsys.param_specs(cfg, fsdp=fsdp, tp="model")
        p_specs = dict(p_specs, table_scale=P(fsdp + ("model",)))
    if "modeltable" in variant:
        # §Perf: shard table rows over 'model' ONLY (replicated across data
        # axes) — lookups stay inside 16-way groups instead of 512-way
        p_specs = dict(p_specs, table=P("model", None))
        if "int8table" in variant:
            p_specs = dict(p_specs, table_scale=P("model"))
    params = jax.eval_shape(lambda: recsys.init_params(cfg, jax.random.PRNGKey(0)))
    f = cfg.n_sparse

    if shape.kind == "train":
        b = shape.params["batch"]
        opt_cfg = adamw.AdamWConfig()
        step_fn = tstep.make_train_step(functools.partial(recsys.loss_fn, cfg), opt_cfg)
        state = jax.eval_shape(
            lambda: tstep.init_state(recsys.init_params(cfg, jax.random.PRNGKey(0)))
        )
        state_specs = tstep.TrainState(
            params=p_specs, opt=adamw.OptState(step=P(), m=p_specs, v=p_specs), ef=None
        )
        batch_sds = {"ids": _sds((b, f), jnp.int32), "labels": _sds((b,), jnp.float32)}
        batch_specs = {"ids": P(all_axes, None), "labels": P(all_axes)}
        return Cell(
            spec.arch_id, shape.name, "train",
            fn=step_fn,
            args=(state, batch_sds),
            in_shardings=(_shard(mesh, state_specs), _shard(mesh, batch_specs)),
            meta=dict(
                model_flops=3.0 * recsys_flops(cfg, b),
                n_params=cfg.n_params(),
                lookup_bytes=b * f * cfg.embed_dim * 4,
                loop_mult=1.0,
            ),
        )

    if shape.kind == "serve":
        b = shape.params["batch"]
        fn = functools.partial(recsys.forward, cfg)
        ids = _sds((b, f), jnp.int32)
        return Cell(
            spec.arch_id, shape.name, "serve",
            fn=fn,
            args=(params, ids),
            in_shardings=(_shard(mesh, p_specs), NamedSharding(mesh, P(all_axes, None))),
            meta=dict(
                model_flops=recsys_flops(cfg, b),
                n_params=cfg.n_params(),
                lookup_bytes=b * f * cfg.embed_dim * 4,
                loop_mult=1.0,
            ),
        )

    if shape.kind == "retrieval":
        nc = shape.params["n_candidates"]
        nc_pad = _round_up(nc, mesh.size)
        fn = functools.partial(recsys.retrieval_scores, cfg)
        ids = _sds((1, f), jnp.int32)
        cand = _sds((nc_pad,), jnp.int32)
        return Cell(
            spec.arch_id, shape.name, "retrieval",
            fn=fn,
            args=(params, ids, cand),
            in_shardings=(
                _shard(mesh, p_specs),
                NamedSharding(mesh, P(None, None)),
                NamedSharding(mesh, P(all_axes)),
            ),
            meta=dict(
                model_flops=recsys_flops(cfg, 1) + 2.0 * nc * cfg.embed_dim,
                n_params=cfg.n_params(),
                lookup_bytes=nc * cfg.embed_dim * 4,
                loop_mult=1.0,
            ),
        )
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# graph500 (the paper's workload)
# ---------------------------------------------------------------------------


def _graph500_cell(
    spec: cfgs.ArchSpec, shape: cfgs.ShapeSpec, mesh: Mesh, variant: str = "baseline"
) -> Cell:
    from repro.configs.graph500 import Graph500Config

    cfg: Graph500Config = spec.model_config()
    scale, ef = shape.params["scale"], shape.params["edgefactor"]
    rows, cols = meshlib.grid_rows_cols(mesh)
    n = _round_up(1 << scale, rows * cols * 1024)
    part = Partition2D(n=n, n_orig=1 << scale, rows=rows, cols=cols)
    m_sym = 2 * ef * (1 << scale)
    # baseline: 4x mean block capacity (pessimistic RMAT-skew headroom);
    # §Perf variant 'ecap15': 1.5x, justified by measured block imbalance of
    # label-permuted RMAT graphs (benchmarks/frontier_stats + EXPERIMENTS.md)
    skew = 1.5 if "ecap15" in variant else 4.0
    e_cap = _round_up(int(skew * m_sym) // (rows * cols), 1024)
    row_axes = meshlib.fsdp_axes(mesh)
    mode = "bitmap" if "bitmaponly" in variant else cfg.mode
    bcfg = dbfs.DistBFSConfig(row_axes=row_axes, col_axis="model", mode=mode)
    fn = dbfs.build_bfs(mesh, part, bcfg)
    ax_sizes = tuple(mesh.shape[a] for a in bcfg.all_axes)
    blk = _sds(ax_sizes + (e_cap,), jnp.int32)
    blk_sh = NamedSharding(mesh, P(*bcfg.row_axes, bcfg.col_axis, None))
    return Cell(
        spec.arch_id, shape.name, "bfs",
        fn=fn,
        args=(blk, blk, _sds((), jnp.int32)),
        in_shardings=(blk_sh, blk_sh, NamedSharding(mesh, P())),
        meta=dict(
            model_flops=2.0 * m_sym,  # one compare+select per directed edge
            n_edges=m_sym,
            e_cap=e_cap,
            loop_mult=8.0,  # typical RMAT BFS depth
        ),
    )


# ---------------------------------------------------------------------------


def build_cell(
    arch_id: str, shape_name: str, mesh: Mesh, variant: str = "baseline"
) -> Cell:
    spec = cfgs.get(arch_id)
    shape = spec.shape(shape_name)
    if shape.kind == "skip":
        return Cell(arch_id, shape_name, "skip", skip_reason=shape.skip_reason)
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh, variant)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, mesh, variant)
    if spec.family == "graph":
        return _graph500_cell(spec, shape, mesh, variant)
    raise ValueError(spec.family)


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in cfgs.list_archs():
        for shape in cfgs.get(arch).shapes:
            out.append((arch, shape.name))
    return out
