import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax-importing module):
jax locks the device count at first init, and the dry-run needs 512
placeholder host devices for the (2,16,16) production mesh.  Smoke tests
and benchmarks do NOT import this module and see 1 device.

Per cell this driver records:
  * compile success (the deliverable: sharding coherence on the mesh),
  * ``compiled.memory_analysis()``   — proves the program fits per device,
  * ``compiled.cost_analysis()``     — FLOPs / bytes for §Roofline,
  * parsed collective bytes          — §Roofline's third term,
  * analytic MODEL_FLOPS and the useful-flop ratio.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
    PYTHONPATH=src python -m repro.launch.dryrun --report   # table from JSONs

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax

from repro import compat
from repro.launch import cells as cellslib
from repro.launch import mesh as meshlib
from repro.launch import roofline

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def run_cell(
    arch: str, shape: str, multi_pod: bool, out_dir: str, variant: str = "baseline"
) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "error",
        "variant": variant,
    }
    t0 = time.time()
    try:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
        cell = cellslib.build_cell(arch, shape, mesh, variant=variant)
        if cell.kind == "skip":
            rec.update(status="skip", skip_reason=cell.skip_reason)
            return _write(rec, out_dir)
        rec["meta"] = {
            k: (float(v) if isinstance(v, (int, float)) else v)
            for k, v in cell.meta.items()
        }
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        with compat.set_mesh(mesh):  # bare-PartitionSpec constraints need a mesh
            lowered = jitted.lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        loop_mult = float(cell.meta.get("loop_mult", 1.0))
        hlo = compiled.as_text()
        terms = roofline.terms_from_compiled(
            compiled,
            chips=mesh.size,
            model_flops=float(cell.meta["model_flops"]),
            loop_mult=loop_mult,
            hlo_text=hlo,
        )
        coll = roofline.parse_collectives(hlo, loop_mult=loop_mult)
        rec["roofline"] = {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops": terms.model_flops,
            "hlo_flops_scaled": terms.hlo_flops,
            "hlo_bytes_scaled": terms.hlo_bytes,
            "collective_bytes": terms.collective_bytes,
            "collective_breakdown": coll.per_op,
            "useful_flop_ratio": terms.useful_flop_ratio,
            "roofline_fraction": terms.roofline_fraction,
        }
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — per-cell isolation is the point
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return _write(rec, out_dir)


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if rec.get("variant", "baseline") == "baseline" else f"__{rec['variant']}"
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = rec.get("skip_reason", rec.get("error", ""))[:90]
    dom = rec.get("roofline", {}).get("dominant", "")
    print(f"[{status:5s}] {rec['arch']:22s} {rec['shape']:14s} {rec['mesh']:8s} "
          f"{rec.get('total_s', 0):7.1f}s {dom:10s} {extra}")
    return rec


def report(out_dir: str) -> None:
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                rows.append(json.load(f))
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skip" for r in rows)
    err = sum(r["status"] == "error" for r in rows)
    print(f"cells: {len(rows)}  ok={ok} skip={skip} error={err}")
    for r in rows:
        if r["status"] == "error":
            print(f"  ERROR {r['arch']}/{r['shape']}/{r['mesh']}: {r.get('error')}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.report:
        report(args.out)
        return

    pods = [args.multipod] if not args.both_meshes else [False, True]
    if args.all:
        for arch, shape in cellslib.all_cells():
            for mp in pods:
                run_cell(arch, shape, mp, args.out, variant=args.variant)
        report(args.out)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    for mp in pods:
        run_cell(args.arch, args.shape, mp, args.out, variant=args.variant)


if __name__ == "__main__":
    main()
