"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 = 256 chips single pod, (2,16,16) = 512 chips for
the two-pod configuration.  The BFS grid folds ("pod","data") into its row
axis, so the same mesh serves models (FSDP x TP) and the paper's 2D graph
partition.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Data-parallel / FSDP axes = everything except the tensor axis."""
    return tuple(a for a in mesh.axis_names if a != "model")


def grid_rows_cols(mesh: jax.sharding.Mesh) -> tuple[int, int]:
    """BFS / 2D-GNN grid geometry: rows = product of FSDP axes, cols = TP."""
    rows = 1
    for a in fsdp_axes(mesh):
        rows *= mesh.shape[a]
    return rows, mesh.shape["model"]
