"""End-to-end training launcher with checkpoint-restart and fault handling.

Drives any registered arch's *smoke-scale* config on the local devices (the
full configs are exercised by the dry-run; this launcher proves the whole
runtime: data -> step -> watchdog -> async checkpoint -> resume).

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50

Restart the same command after killing it: training resumes from the newest
complete checkpoint at the exact step (deterministic pipeline).
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import common as cfgs
from repro.data import recsys as drecsys
from repro.data import tokens as dtokens
from repro.models import gnn, recsys
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import checkpoint, fault
from repro.train import step as tstep


def _build(arch_id: str, batch: int, seq_len: int, opt_cfg: adamw.AdamWConfig):
    spec = cfgs.get(arch_id)
    cfg = spec.smoke_config()
    key = jax.random.PRNGKey(0)
    if spec.family == "lm":
        params = tfm.init_params(cfg, key)
        loss = functools.partial(tfm.loss_fn, cfg)
        pipe = dtokens.TokenPipelineConfig(vocab=cfg.vocab, batch=batch, seq_len=seq_len)
        batch_fn = lambda step: {  # noqa: E731
            k: jnp.asarray(v) for k, v in dtokens.batch_at(pipe, step).items()
        }
    elif spec.family == "recsys":
        params = recsys.init_params(cfg, key)
        loss = functools.partial(recsys.loss_fn, cfg)
        pipe = drecsys.ClickLogConfig(table_sizes=cfg.resolved_tables(), batch=batch)
        batch_fn = lambda step: {  # noqa: E731
            k: jnp.asarray(v) for k, v in drecsys.batch_at(pipe, step).items()
        }
    elif spec.family == "gnn":
        from repro.data import graphs as dgraphs

        params = gnn.init(cfg, key)
        loss = functools.partial(gnn.loss_fn, cfg)
        gb = dgraphs.synthetic_graph(512, 2048, cfg.d_in, seed=0, n_classes=cfg.d_out)
        g = gnn.Graph(
            nf=jnp.asarray(gb.nf), src=jnp.asarray(gb.src), dst=jnp.asarray(gb.dst),
            pos=jnp.asarray(gb.pos),
        )
        tgt = jnp.asarray(gb.targets)
        batch_fn = lambda step: {"graph": g, "targets": tgt}  # noqa: E731
    else:
        raise ValueError(f"train launcher does not drive family {spec.family!r}")
    step_fn = jax.jit(tstep.make_train_step(loss, opt_cfg))
    return params, step_fn, batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1), total_steps=args.steps
    )
    params, step_fn, batch_fn = _build(args.arch, args.batch, args.seq_len, opt_cfg)

    start_step = 0
    state = tstep.init_state(params)
    ckpt = None
    if args.ckpt_dir:
        state, start_step = fault.resume_or_init(
            lambda: tstep.init_state(params), args.ckpt_dir
        )
        ckpt = checkpoint.AsyncCheckpointer(args.ckpt_dir)
        if start_step:
            print(f"resumed from checkpoint at step {start_step}")

    dog = fault.StepWatchdog()
    losses = []
    for step in range(start_step, args.steps):
        dog.start()
        state, metrics = step_fn(state, batch_fn(step))
        loss = float(metrics["loss"])
        verdict = dog.stop()
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(adamw.wsd_schedule(opt_cfg, jnp.int32(step))):.2e} {verdict}")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.submit(state, step)
    if ckpt is not None:
        ckpt.submit(state, args.steps - 1)
        ckpt.wait()
    first = np.mean(losses[: max(len(losses) // 10, 1)])
    last = np.mean(losses[-max(len(losses) // 10, 1):])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}); "
          f"stragglers: {len(dog.stragglers)}")


if __name__ == "__main__":
    main()
