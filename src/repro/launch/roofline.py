"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / (chips x peak_FLOPs)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

Hardware: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Caveats handled here:

* ``cost_analysis`` visits every op ONCE — while-loop bodies (scan over
  layers, BFS levels) are not multiplied by trip count.  We parse the HLO,
  attribute ops to computations, discover while-body computations from the
  ``while(... body=%B)`` ops, and scale both FLOPs/bytes heuristics and
  collective bytes by a caller-supplied ``loop_mult`` for ops inside them.
* collective bytes are not in cost_analysis at all: we sum the result-shape
  bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute in the (SPMD, per-device) module; all-reduce counts
  2x (reduce + broadcast phases of a ring).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes / s / chip
LINK_BW = 50e9  # bytes / s / link (ICI)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\(|\w)[^=]*?)\s*(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op: dict[str, int]  # op kind -> per-device bytes (loop-scaled)
    total_bytes: int
    n_ops: int

    def breakdown(self) -> str:
        return ", ".join(f"{k}:{v / 1e6:.1f}MB" for k, v in sorted(self.per_op.items()))


_NAME_REF_RE = re.compile(r"%([\w\.\-]+)")


def parse_collectives(hlo_text: str, loop_mult: float = 1.0) -> CollectiveStats:
    """Sum collective result bytes from SPMD HLO text.

    loop_mult multiplies ops *transitively reachable* from a while-body
    computation (scan bodies, and the conditional branches / fusions /
    reducers they call) — discovered by building the computation call graph
    from %name references."""
    # pass 1: computation spans, per-computation collectives, call edges
    comp_ops: dict[str, list[tuple[str, int]]] = {}
    comp_edges: dict[str, set[str]] = {}
    bodies: set[str] = set()
    comp_names: set[str] = set()
    current = ""
    lines = hlo_text.splitlines()
    for line in lines:
        if line and not line.startswith(" "):
            mc = _COMP_RE.match(line.strip())
            if mc:
                current = mc.group(1)
                comp_names.add(current)
                comp_ops.setdefault(current, [])
                comp_edges.setdefault(current, set())
                continue
        if not current:
            continue
        if " while(" in line or "=while(" in line:
            m = _WHILE_BODY_RE.search(line)
            if m:
                bodies.add(m.group(1))
        m = _OP_RE.search(line)
        if m:
            b = _shape_bytes(m.group(1))
            if m.group(2) == "all-reduce":
                b *= 2  # ring all-reduce moves ~2x the operand
            comp_ops[current].append((m.group(2), b))
        for ref in _NAME_REF_RE.findall(line):
            comp_edges[current].add(ref)

    # pass 2: computations transitively reachable from any while body
    scaled: set[str] = set()
    stack = [b for b in bodies]
    while stack:
        c = stack.pop()
        if c in scaled or c not in comp_ops:
            continue
        scaled.add(c)
        stack.extend(e for e in comp_edges.get(c, ()) if e in comp_names)

    per_op: dict[str, int] = {}
    n_ops = 0
    for comp, ops in comp_ops.items():
        mult = loop_mult if comp in scaled else 1.0
        for kind, b in ops:
            per_op[kind] = per_op.get(kind, 0) + int(b * mult)
            n_ops += 1
    return CollectiveStats(per_op=per_op, total_bytes=sum(per_op.values()), n_ops=n_ops)


@dataclasses.dataclass
class CommStatsComparison:
    """CommStats-expected vs HLO-parsed collective bytes, per op kind."""

    expected: dict[str, int]  # op kind -> bytes, from CommStats (loop body, x1)
    parsed: dict[str, int]  # op kind -> bytes, from parse_collectives
    per_phase: dict[str, int]  # CommStats phase -> bytes

    @property
    def match(self) -> bool:
        keys = set(self.expected) | set(self.parsed)
        return all(self.expected.get(k, 0) == self.parsed.get(k, 0) for k in keys)

    def diff(self) -> dict[str, tuple[int, int]]:
        keys = set(self.expected) | set(self.parsed)
        return {
            k: (self.expected.get(k, 0), self.parsed.get(k, 0))
            for k in sorted(keys)
            if self.expected.get(k, 0) != self.parsed.get(k, 0)
        }


def compare_comm_stats(stats, hlo_text: str) -> CommStatsComparison:
    """Check CommStats accounting against the compiled program's HLO.

    ``stats`` is a :class:`repro.comm.CommStats` filled at trace time (one
    entry per collective op); ``hlo_text`` the post-optimization HLO of the
    same program.  Both sides use the per-device result-shape convention
    with ring all-reduce counted 2x, and neither scales loop bodies
    (``loop_mult=1``), so the totals must agree per op kind if the
    accounting is faithful.
    """
    parsed = parse_collectives(hlo_text, loop_mult=1.0)
    return CommStatsComparison(
        expected=stats.per_op(),
        parsed=dict(parsed.per_op),
        per_phase=stats.per_phase(),
    )


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float  # loop-scaled, per device
    hlo_bytes: float
    collective_bytes: float
    model_flops: float  # analytic (6ND etc.), GLOBAL
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/dispatch/mask waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction if the program ran at its bound:
        (MODEL_FLOPS / peak-of-all-chips) / bound-time."""
        ideal_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal_s / self.bound_s if self.bound_s else 0.0


def terms_from_compiled(
    compiled,
    chips: int,
    model_flops: float,
    loop_mult: float = 1.0,
    hlo_text: str | None = None,
) -> RooflineTerms:
    """Derive the three terms from a compiled executable.

    FLOPs/bytes: cost_analysis counts while bodies once; we approximate the
    loop-scaled totals by multiplying the WHOLE program cost by loop_mult
    when the dominant cost sits inside the loop (scan-over-layers LMs, BFS)
    — callers pass loop_mult = n_layers (or expected BFS levels).  The
    top-level (embedding/head) contribution is small by comparison and this
    keeps the estimate conservative (over-counts slightly).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0)) * loop_mult
    bytes_ = float(ca.get("bytes accessed", 0.0)) * loop_mult
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text, loop_mult=loop_mult)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=coll.total_bytes / LINK_BW,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        collective_bytes=coll.total_bytes,
        model_flops=model_flops,
        chips=chips,
    )
