"""Synthetic graph + integer-stream generators (paper §2.7, §5.3.2)."""

from repro.graphgen.kronecker import kronecker_edges, rmat_edges
from repro.graphgen.builder import build_csr, CSRGraph, symmetrize, relabel_by_degree
from repro.graphgen.zipf import zipf_stream, sorted_id_stream

__all__ = [
    "kronecker_edges",
    "rmat_edges",
    "build_csr",
    "CSRGraph",
    "symmetrize",
    "relabel_by_degree",
    "zipf_stream",
    "sorted_id_stream",
]
