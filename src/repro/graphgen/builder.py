"""Edge list → CSR construction (paper "Kernel 1": graph construction).

The Graph 500 benchmark times graph construction separately from BFS; this
module is that kernel.  It produces a :class:`CSRGraph` holding CSR arrays
plus the symmetric COO edge arrays the edge-centric BFS consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row graph + symmetric COO view.

    Attributes:
      n: vertex count.
      row_ptr: (n+1,) int64 CSR offsets.
      col_idx: (m,) int32 CSR adjacency (deduped, self-loop-free, symmetric).
      src/dst: (m,) int32 COO view of the same edges (sorted by src).
      m_input: number of *input* (pre-dedup, directed) edges — the TEPS
        denominator uses input edges within the traversed component.
    """

    n: int
    row_ptr: np.ndarray
    col_idx: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    m_input: int

    @property
    def m(self) -> int:
        return int(self.col_idx.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.col_idx[self.row_ptr[v] : self.row_ptr[v + 1]]


def symmetrize(edges: np.ndarray) -> np.ndarray:
    """Append reversed edges: BFS treats the Graph500 graph as undirected."""
    return np.concatenate([edges, edges[:, ::-1]], axis=0)


def build_csr(
    edges: np.ndarray,
    n: int | None = None,
    drop_self_loops: bool = True,
    dedupe: bool = True,
    symmetrize_edges: bool = True,
) -> CSRGraph:
    """Build a symmetric CSR graph from an (m, 2) directed edge array.

    Pass ``symmetrize_edges=False`` when the input is already symmetric
    (e.g. rebuilding from another CSRGraph's src/dst arrays)."""
    edges = np.asarray(edges, dtype=np.int64)
    m_input = int(edges.shape[0])
    if n is None:
        n = int(edges.max()) + 1 if edges.size else 0

    sym = symmetrize(edges) if symmetrize_edges else edges
    if drop_self_loops:
        sym = sym[sym[:, 0] != sym[:, 1]]
    # Sort by (src, dst); dedupe.
    order = np.lexsort((sym[:, 1], sym[:, 0]))
    sym = sym[order]
    if dedupe and sym.shape[0]:
        keep = np.ones(sym.shape[0], dtype=bool)
        keep[1:] = np.any(sym[1:] != sym[:-1], axis=1)
        sym = sym[keep]

    src = sym[:, 0].astype(np.int32)
    dst = sym[:, 1].astype(np.int32)
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRGraph(
        n=n, row_ptr=row_ptr, col_idx=dst.copy(), src=src, dst=dst, m_input=m_input
    )


def relabel_by_degree(g: CSRGraph) -> tuple[CSRGraph, np.ndarray]:
    """Paper §3.1 "vertex sorting": relabel vertices by descending degree.

    High-degree vertices get small ids, so the frontier's sorted id sequence
    concentrates near zero with small gaps — exactly the numerical property
    the paper's delta+bitpack codec exploits (§5.4.1).  Returns the relabeled
    graph and the permutation ``new_id = perm[old_id]``.
    """
    deg = g.degrees()
    order = np.argsort(-deg, kind="stable")  # old ids in new order
    perm = np.empty_like(order)
    perm[order] = np.arange(g.n)
    new_edges = np.stack([perm[g.src], perm[g.dst]], axis=1)
    rebuilt = build_csr(
        new_edges, n=g.n, drop_self_loops=False, dedupe=False, symmetrize_edges=False
    )
    # m_input is a property of the original generator stream; preserve it.
    rebuilt = dataclasses.replace(rebuilt, m_input=g.m_input)
    return rebuilt, perm


def block_pad(g: CSRGraph, multiple: int) -> CSRGraph:
    """Pad vertex count to a multiple (replaces the paper's odd-rank residuum
    handling, §7.2.1 — static padding instead of special-case code paths)."""
    n_pad = -(-g.n // multiple) * multiple
    if n_pad == g.n:
        return g
    row_ptr = np.concatenate(
        [g.row_ptr, np.full(n_pad - g.n, g.row_ptr[-1], dtype=g.row_ptr.dtype)]
    )
    return CSRGraph(
        n=n_pad,
        row_ptr=row_ptr,
        col_idx=g.col_idx,
        src=g.src,
        dst=g.dst,
        m_input=g.m_input,
    )
