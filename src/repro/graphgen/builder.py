"""Edge list → CSR construction (paper "Kernel 1": graph construction).

The Graph 500 benchmark times graph construction separately from BFS; this
module is that kernel.  It produces a :class:`CSRGraph` holding CSR arrays
plus the symmetric COO edge arrays the edge-centric BFS consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row graph + symmetric COO view.

    Attributes:
      n: vertex count.
      row_ptr: (n+1,) int64 CSR offsets.
      col_idx: (m,) int32 CSR adjacency (deduped, self-loop-free, symmetric).
      src/dst: (m,) int32 COO view of the same edges (sorted by src).
      m_input: number of *input* (pre-dedup, directed) edges — the TEPS
        denominator uses input edges within the traversed component.
    """

    n: int
    row_ptr: np.ndarray
    col_idx: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    m_input: int

    @property
    def m(self) -> int:
        return int(self.col_idx.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.col_idx[self.row_ptr[v] : self.row_ptr[v + 1]]


def symmetrize(edges: np.ndarray) -> np.ndarray:
    """Append reversed edges: BFS treats the Graph500 graph as undirected."""
    return np.concatenate([edges, edges[:, ::-1]], axis=0)


def build_csr(
    edges: np.ndarray,
    n: int | None = None,
    drop_self_loops: bool = True,
    dedupe: bool = True,
    symmetrize_edges: bool = True,
) -> CSRGraph:
    """Build a symmetric CSR graph from an (m, 2) directed edge array.

    Pass ``symmetrize_edges=False`` when the input is already symmetric
    (e.g. rebuilding from another CSRGraph's src/dst arrays)."""
    edges = np.asarray(edges, dtype=np.int64)
    m_input = int(edges.shape[0])
    if n is None:
        n = int(edges.max()) + 1 if edges.size else 0

    sym = symmetrize(edges) if symmetrize_edges else edges
    if drop_self_loops:
        sym = sym[sym[:, 0] != sym[:, 1]]
    # Sort by (src, dst); dedupe.
    order = np.lexsort((sym[:, 1], sym[:, 0]))
    sym = sym[order]
    if dedupe and sym.shape[0]:
        keep = np.ones(sym.shape[0], dtype=bool)
        keep[1:] = np.any(sym[1:] != sym[:-1], axis=1)
        sym = sym[keep]

    src = sym[:, 0].astype(np.int32)
    dst = sym[:, 1].astype(np.int32)
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRGraph(
        n=n, row_ptr=row_ptr, col_idx=dst.copy(), src=src, dst=dst, m_input=m_input
    )


def relabel_by_degree(g: CSRGraph) -> tuple[CSRGraph, np.ndarray]:
    """Paper §3.1 "vertex sorting": relabel vertices by descending degree.

    High-degree vertices get small ids, so the frontier's sorted id sequence
    concentrates near zero with small gaps — exactly the numerical property
    the paper's delta+bitpack codec exploits (§5.4.1).  Returns the relabeled
    graph and the permutation ``new_id = perm[old_id]``.
    """
    deg = g.degrees()
    order = np.argsort(-deg, kind="stable")  # old ids in new order
    perm = np.empty_like(order)
    perm[order] = np.arange(g.n)
    new_edges = np.stack([perm[g.src], perm[g.dst]], axis=1)
    rebuilt = build_csr(
        new_edges, n=g.n, drop_self_loops=False, dedupe=False, symmetrize_edges=False
    )
    # m_input is a property of the original generator stream; preserve it.
    rebuilt = dataclasses.replace(rebuilt, m_input=g.m_input)
    return rebuilt, perm


# ---------------------------------------------------------------------------
# ELL / hybrid local-expansion containers (built at partition time)
# ---------------------------------------------------------------------------


def _round_up(x: int, multiple: int) -> int:
    return -(-x // multiple) * multiple


def ell_from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n_rows: int,
    n_cols: int,
    k: int,
    width: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Degree-split one local COO edge block at ``k``.

    Rows (destinations) with degree <= ``k`` move *entirely* into a dense
    destination-major ``(n_rows, width)`` ELL slab (sentinel-padded with
    ``n_cols``, which never hits a frontier bitmap); heavier rows keep all
    their edges in the returned COO residue — each row's edge set lives in
    exactly one structure, so ``min(slab result, residue result)`` equals
    the flat segment_min over the union.  ``width`` (defaults to ``k``)
    lets hybrid blocks share one slab width across blocks with different
    per-block splits.  Edges at the (``n_cols``, ``n_rows``) sentinels are
    dropped, mirroring how the gathers mask them.
    """
    width = k if width is None else width
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    valid = (src < n_cols) & (dst < n_rows)
    s, d = src[valid], dst[valid]
    deg = np.bincount(d, minlength=n_rows)
    in_slab = deg[d] <= k
    nbr = np.full((n_rows, max(width, 1)), n_cols, np.int32)
    sd, ss = d[in_slab], s[in_slab]
    order = np.argsort(sd, kind="stable")
    sd, ss = sd[order], ss[order]
    starts = np.searchsorted(sd, np.arange(n_rows))
    rank = np.arange(sd.size) - starts[sd]
    nbr[sd, rank] = ss
    return nbr, s[~in_slab].astype(np.int32), d[~in_slab].astype(np.int32)


def select_split_k(
    degrees: np.ndarray, waste_budget: float = 0.5, multiple: int = 8
) -> int:
    """Pick the hybrid degree split from a block's degree histogram.

    Chooses the largest ``k`` (a ``multiple``-aligned slab width) whose ELL
    slab keeps padding waste under the budget, where waste is the fraction
    of slab slots holding sentinels:

        waste(k) = 1 - (edges of rows with degree <= k) / (n_rows * k)

    Covered edges grow sublinearly in ``k`` on skewed degree distributions
    (hubs are few), so the largest affordable ``k`` moves the most edges
    onto the dense slab while the hub residue stays COO.  Falls back to the
    smallest slab when even that exceeds the budget (near-empty blocks).
    """
    deg = np.asarray(degrees)
    n_rows = int(deg.size)
    max_deg = int(deg.max(initial=0))
    if n_rows == 0 or max_deg == 0:
        return multiple
    hist = np.bincount(deg)
    covered = np.cumsum(np.arange(hist.size) * hist)  # edges of rows deg<=k
    best = multiple
    for k in range(multiple, max_deg + multiple, multiple):
        if covered[min(k, hist.size - 1)] >= (1.0 - waste_budget) * n_rows * k:
            best = k
    return best


def edge_degrees(
    src: np.ndarray, dst: np.ndarray, n_rows: int, n_cols: int
) -> np.ndarray:
    """Per-destination degree over the valid (non-sentinel) edges — THE
    valid-edge convention every container builder shares."""
    src, dst = np.asarray(src), np.asarray(dst)
    valid = (src < n_cols) & (dst < n_rows)
    return np.bincount(dst[valid], minlength=n_rows)[:n_rows]


def ell_graph_arrays(
    src: np.ndarray, dst: np.ndarray, n: int, deg_multiple: int = 8
) -> tuple[np.ndarray, int]:
    """Whole-graph ELL slab for the single-device driver.

    ``k`` covers the heaviest row (rounded to the kernel's degree chunk),
    so the residue is empty — the pure-ELL backend.  Returns (slab, k).
    """
    k = _round_up(max(int(edge_degrees(src, dst, n, n).max(initial=1)), 1),
                  deg_multiple)
    nbr, res_s, _ = ell_from_edges(src, dst, n, n, k)
    assert res_s.size == 0, "pure ELL must cover every row"
    return nbr, k


def hybrid_graph_arrays(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    waste_budget: float = 0.5,
    split_k: int | None = None,
    deg_multiple: int = 8,
    res_multiple: int = 1024,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Whole-graph hybrid COO/ELL split for the single-device driver.

    Returns (slab, residue src, residue dst, k); the residue arrays are
    sentinel-padded ((n, n)) to a static ``res_multiple`` capacity.
    """
    deg = edge_degrees(src, dst, n, n)
    k = split_k or select_split_k(deg, waste_budget, deg_multiple)
    nbr, res_s, res_d = ell_from_edges(src, dst, n, n, k)
    cap = _round_up(max(res_s.size, 1), res_multiple)
    pad = cap - res_s.size
    res_s = np.concatenate([res_s, np.full(pad, n, np.int32)])
    res_d = np.concatenate([res_d, np.full(pad, n, np.int32)])
    return nbr, res_s, res_d, k


def block_pad(g: CSRGraph, multiple: int) -> CSRGraph:
    """Pad vertex count to a multiple (replaces the paper's odd-rank residuum
    handling, §7.2.1 — static padding instead of special-case code paths)."""
    n_pad = -(-g.n // multiple) * multiple
    if n_pad == g.n:
        return g
    row_ptr = np.concatenate(
        [g.row_ptr, np.full(n_pad - g.n, g.row_ptr[-1], dtype=g.row_ptr.dtype)]
    )
    return CSRGraph(
        n=n_pad,
        row_ptr=row_ptr,
        col_idx=g.col_idx,
        src=g.src,
        dst=g.dst,
        m_input=g.m_input,
    )
