"""Graph500 Kronecker (RMAT) edge-list generator (paper §2.7.1).

The Graph 500 specification generates a scale-free graph by recursively
sampling quadrants of the adjacency matrix with probabilities
(A, B, C, D) = (0.57, 0.19, 0.19, 0.05).  ``vertices = 2**scale`` and
``edges = vertices * edgefactor`` (edgefactor 16 per the benchmark).

This is a vectorized numpy implementation: one pass per scale bit over the
whole edge array, identical in distribution to the reference implementation's
per-edge recursion.  Vertex labels are randomly permuted afterwards, as the
spec requires, so that vertex id carries no locality information (the paper's
"vertex sorting" optimization then *re-introduces* locality deliberately —
see :func:`repro.graphgen.builder.relabel_by_degree`).
"""

from __future__ import annotations

import numpy as np

# Graph500 quadrant probabilities.
A, B, C, D = 0.57, 0.19, 0.19, 0.05


def kronecker_edges(
    scale: int,
    edgefactor: int = 16,
    seed: int = 1,
    permute: bool = True,
) -> np.ndarray:
    """Return an int64 array of shape (m, 2) of directed edge endpoints.

    Follows the Graph 500 octave reference: per bit, choose the row/column
    half independently with the RMAT skew, then permute vertex labels and
    shuffle edge order.
    """
    n = 1 << scale
    m = n * edgefactor
    rng = np.random.default_rng(seed)

    ij = np.zeros((2, m), dtype=np.int64)
    ab = A + B
    c_norm = C / (1.0 - ab)
    a_norm = A / ab
    for ib in range(scale):
        ii_bit = rng.random(m) > ab
        jj_bit = rng.random(m) > np.where(ii_bit, c_norm, a_norm)
        ij[0] += (1 << ib) * ii_bit
        ij[1] += (1 << ib) * jj_bit

    if permute:
        perm = rng.permutation(n)
        ij = perm[ij]
        ij = ij[:, rng.permutation(m)]
    return ij.T.copy()


def rmat_edges(
    scale: int,
    edgefactor: int = 16,
    seed: int = 1,
    a: float = A,
    b: float = B,
    c: float = C,
    permute: bool = True,
) -> np.ndarray:
    """General RMAT with tunable skew (used by benchmarks to vary gap entropy)."""
    n = 1 << scale
    m = n * edgefactor
    rng = np.random.default_rng(seed)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    ij = np.zeros((2, m), dtype=np.int64)
    for ib in range(scale):
        ii_bit = rng.random(m) > ab
        jj_bit = rng.random(m) > np.where(ii_bit, c_norm, a_norm)
        ij[0] += (1 << ib) * ii_bit
        ij[1] += (1 << ib) * jj_bit
    if permute:
        perm = rng.permutation(n)
        ij = perm[ij]
    return ij.T.copy()
