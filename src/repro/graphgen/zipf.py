"""Synthetic integer streams for codec benchmarking (paper §5.3.2, Table 5.3).

The paper's codec comparison uses (a) a Zipf synthetic generator with tunable
skewness (TurboPFOR's test harness) and (b) real frontier-queue buffers
extracted from BFS runs (slightly-skewed uniform, 15-bit empirical entropy).
Both stream shapes are reproduced here.
"""

from __future__ import annotations

import numpy as np


def zipf_stream(
    n: int, alpha: float = 1.2, vocab: int = 1 << 20, seed: int = 0
) -> np.ndarray:
    """Zipf-distributed uint32 stream (inverted-index-like data)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs).astype(np.uint32)


def sorted_id_stream(
    n: int, universe: int, seed: int = 0, skew: float = 0.0
) -> np.ndarray:
    """Sorted, unique vertex-id sequence mimicking a frontier queue.

    ``skew`` > 0 biases ids toward 0 (what degree-relabeling produces);
    skew == 0 gives the paper's "uniform, slightly skewed" distribution
    (Fig 5.2 / Table 5.3).
    """
    rng = np.random.default_rng(seed)
    if skew > 0.0:
        u = rng.random(min(4 * n, universe)) ** (1.0 + skew)
        ids = np.unique((u * universe).astype(np.uint64))
    else:
        ids = np.unique(rng.integers(0, universe, size=min(2 * n, universe * 2)))
    if ids.shape[0] > n:
        ids = np.sort(rng.choice(ids, size=n, replace=False))
    return ids.astype(np.uint32)


def empirical_entropy_bits(values: np.ndarray) -> float:
    """Shannon entropy (bits/symbol) of a discrete stream (paper eq. (2))."""
    _, counts = np.unique(values, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
