"""Decoder-only transformer family (dense, GQA/MQA, MLA, fine-grained MoE).

Design notes (DESIGN.md §6):

* **scan-over-layers**: layer params carry a leading (L,) dim; the decode
  stack is one ``lax.scan`` body (+ optional ``jax.checkpoint``).  Keeps HLO
  small enough to dry-run 62-layer models on the 512-way mesh.
* **blockwise attention**: online-softmax over KV chunks (Rabe-Staats /
  flash-style) so 32k prefill never materializes S x S scores.
* **MLA** (DeepSeek-V2): low-rank KV latent cache; decode uses the absorbed
  form (q projected into latent space) so the cache stays (B, S, r + rope).
* **MoE**: GShard-style capacity dispatch with fine-grained routing groups
  (one-hot einsum — TPU-native, no dynamic scatter); optional shared experts
  (DeepSeek-V2) and int8-quantized dispatch payloads (beyond-paper).
* **sharding**: parameter PartitionSpecs from :func:`param_specs` — FSDP
  over the data axes, tensor parallelism over 'model'; activations batch-
  sharded over data, KV caches sequence-sharded over 'model'.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # MoE (0 experts = dense)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_group: int = 512  # routing-group length (tokens)
    capacity_factor: float = 1.25
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # misc
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    q_chunk: int = 512
    kv_chunk: int = 1024
    scan_layers: bool = True
    remat: bool = True
    # 'nothing' = full per-layer recompute (flash-style; discards attention
    # score chunks in backward). 'dots' = save dot outputs — keeps the
    # blockwise-attention score tensors alive across the layer scan, which
    # costs TBs/device at 4k x 1M tokens (EXPERIMENTS.md §Perf iteration 1).
    remat_policy: str = "nothing"
    # rematerialize each q-chunk's online-softmax pass in backward (the
    # flash-attention backward strategy) instead of storing per-KV-chunk
    # probability tensors (§Perf iteration 2)
    attn_remat: bool = True
    # explicit sharding pins for MoE dispatch intermediates (set by the
    # launcher; empty = let GSPMD propagate). §Perf iteration 3.
    moe_dp_axes: tuple = ()
    moe_tp_axis: str = ""
    # expert weight layout: 'd' shards the model dim over FSDP axes (weights
    # re-gathered per layer); 'ff' shards d_ff_expert over FSDP axes so
    # expert weights stay resident and only activations reduce (§Perf it. 4)
    expert_shard: str = "d"
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so embed/lm_head shard on any mesh axis
        (MiniCPM's 122753 is not divisible by 16); pad logits are masked."""
        return -(-self.vocab // 256) * 256

    @property
    def qk_head_dim(self) -> int:
        return (self.qk_nope_dim + self.qk_rope_dim) if self.use_mla else self.head_dim

    @property
    def cache_width(self) -> int:
        """Per-token KV cache width (the MLA memory win shows up here)."""
        if self.use_mla:
            return self.kv_lora_rank + self.qk_rope_dim
        return 2 * self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        d, l = self.d_model, self.n_layers
        if self.use_mla:
            q_in = (
                self.q_lora_rank * (d + self.n_heads * self.qk_head_dim)
                if self.q_lora_rank
                else d * self.n_heads * self.qk_head_dim
            )
            attn = (
                q_in
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        if self.is_moe:
            ffn = d * self.n_experts + 3 * d * self.d_ff_expert * (
                self.n_experts + self.n_shared_experts
            )
        else:
            ffn = 3 * d * self.d_ff
        return l * (attn + ffn + 2 * d) + 2 * self.vocab * d + d

    def n_active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        full = self.n_params()
        ffn_all = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
        ffn_act = 3 * d * self.d_ff_expert * (self.top_k + self.n_shared_experts)
        return full - l * (ffn_all - ffn_act)


# ---------------------------------------------------------------------------
# init + sharding specs
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale_axis=0):
    scale = 1.0 / max(shape[scale_axis], 1) ** 0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    keys = iter(jax.random.split(key, 64))
    d, l, dt = cfg.d_model, cfg.n_layers, cfg.param_dtype

    def stack(shape):
        return (l,) + shape if cfg.scan_layers else (l,) + shape

    layer: Params = {
        "ln1": jnp.ones(stack((d,)), dt),
        "ln2": jnp.ones(stack((d,)), dt),
    }
    if cfg.use_mla:
        if cfg.q_lora_rank:
            layer["wq_a"] = _dense(next(keys), stack((d, cfg.q_lora_rank)), dt, 1)
            layer["wq_b"] = _dense(
                next(keys), stack((cfg.q_lora_rank, cfg.n_heads * cfg.qk_head_dim)), dt, 1
            )
        else:
            layer["wq"] = _dense(next(keys), stack((d, cfg.n_heads * cfg.qk_head_dim)), dt, 1)
        layer["wkv_a"] = _dense(
            next(keys), stack((d, cfg.kv_lora_rank + cfg.qk_rope_dim)), dt, 1
        )
        layer["wkv_b"] = _dense(
            next(keys),
            stack((cfg.kv_lora_rank, cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim))),
            dt,
            1,
        )
        layer["wo"] = _dense(next(keys), stack((cfg.n_heads * cfg.v_head_dim, d)), dt, 1)
    else:
        layer["wq"] = _dense(next(keys), stack((d, cfg.n_heads * cfg.head_dim)), dt, 1)
        layer["wk"] = _dense(next(keys), stack((d, cfg.n_kv_heads * cfg.head_dim)), dt, 1)
        layer["wv"] = _dense(next(keys), stack((d, cfg.n_kv_heads * cfg.head_dim)), dt, 1)
        layer["wo"] = _dense(next(keys), stack((cfg.n_heads * cfg.head_dim, d)), dt, 1)
    if cfg.is_moe:
        e, fe = cfg.n_experts, cfg.d_ff_expert
        layer["router"] = _dense(next(keys), stack((d, e)), dt, 1)
        layer["we_gate"] = _dense(next(keys), stack((e, d, fe)), dt, 2)
        layer["we_up"] = _dense(next(keys), stack((e, d, fe)), dt, 2)
        layer["we_down"] = _dense(next(keys), stack((e, fe, d)), dt, 2)
        if cfg.n_shared_experts:
            fs = cfg.n_shared_experts * fe
            layer["ws_gate"] = _dense(next(keys), stack((d, fs)), dt, 1)
            layer["ws_up"] = _dense(next(keys), stack((d, fs)), dt, 1)
            layer["ws_down"] = _dense(next(keys), stack((fs, d)), dt, 1)
    else:
        layer["w_gate"] = _dense(next(keys), stack((d, cfg.d_ff)), dt, 1)
        layer["w_up"] = _dense(next(keys), stack((d, cfg.d_ff)), dt, 1)
        layer["w_down"] = _dense(next(keys), stack((cfg.d_ff, d)), dt, 1)

    return {
        "embed": _dense(next(keys), (cfg.padded_vocab, d), dt, 1),
        "layers": layer,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": _dense(next(keys), (d, cfg.padded_vocab), dt, 0),
    }


def param_specs(cfg: TransformerConfig, fsdp: tuple[str, ...] = ("data",), tp: str = "model"):
    """PartitionSpec pytree matching init_params (FSDP x TP)."""
    f = fsdp if len(fsdp) > 1 else fsdp[0]
    layer: dict[str, P] = {"ln1": P(None, None), "ln2": P(None, None)}
    two_d = P(None, f, tp)  # (L, d_in, d_out): FSDP on in, TP on out
    out_proj = P(None, tp, f)  # (L, h, d): TP on in, FSDP on out
    if cfg.use_mla:
        if cfg.q_lora_rank:
            layer["wq_a"] = P(None, f, None)
            layer["wq_b"] = P(None, None, tp)
        else:
            layer["wq"] = two_d
        layer["wkv_a"] = P(None, f, None)
        layer["wkv_b"] = P(None, None, tp)
        layer["wo"] = out_proj
    else:
        layer.update(wq=two_d, wk=two_d, wv=two_d, wo=out_proj)
    if cfg.is_moe:
        layer["router"] = P(None, f, None)
        if cfg.expert_shard == "ff":
            # experts over TP, d_ff over FSDP: weights stay resident,
            # down-proj partial sums psum over the FSDP axes
            layer["we_gate"] = P(None, tp, None, f)
            layer["we_up"] = P(None, tp, None, f)
            layer["we_down"] = P(None, tp, f, None)
        else:
            layer["we_gate"] = P(None, tp, f, None)
            layer["we_up"] = P(None, tp, f, None)
            layer["we_down"] = P(None, tp, None, f)
        if cfg.n_shared_experts:
            layer.update(ws_gate=two_d, ws_up=two_d, ws_down=out_proj)
    else:
        layer.update(w_gate=two_d, w_up=two_d, w_down=out_proj)
    return {
        "embed": P(tp, f),  # vocab over TP -> masked-psum lookup
        "layers": layer,
        "final_norm": P(None),
        "lm_head": P(f, tp),  # logits vocab-sharded over TP
    }


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, pos, theta):
    """x: (..., S, H, hd) with even hd; pos: (..., S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def _act(cfg, g):
    return jax.nn.gelu(g) if cfg.act == "gelu" else jax.nn.silu(g)


def blockwise_attention(
    q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int, remat_chunks: bool = True
):
    """Online-softmax attention; q (B,S,H,hd), k/v (B,T,KV,hd_v). GQA-aware.

    Never materializes (S, T) scores: scans KV in chunks carrying
    (max, sum, acc) per q position.  Causal masking by absolute position.
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = h // kvh  # query heads per kv head
    scale = hd**-0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    s_pad = -(-s // q_chunk) * q_chunk
    t_pad = -(-t // kv_chunk) * kv_chunk
    q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    nq, nk = s_pad // q_chunk, t_pad // kv_chunk

    qb = q.reshape(b, nq, q_chunk, kvh, g, hd)
    kb = k.reshape(b, nk, kv_chunk, kvh, hd)
    vb = v.reshape(b, nk, kv_chunk, kvh, hd_v)
    q_pos = jnp.arange(s_pad).reshape(nq, q_chunk)
    # padded KV positions pushed past every query so they never attend
    k_pos_flat = jnp.where(jnp.arange(t_pad) < t, jnp.arange(t_pad), s_pad + t_pad)
    k_pos = k_pos_flat.reshape(nk, kv_chunk)

    def per_q_chunk(q_i, qpos_i):
        # q_i: (b, q_chunk, kvh, g, hd)
        def body(carry, inp):
            m, l, acc = carry
            k_j, v_j, kpos_j = inp
            logits = (
                jnp.einsum(
                    "bqkgd,btkd->bkgqt", q_i.astype(jnp.float32), k_j.astype(jnp.float32)
                )
                * scale
            )
            mask = kpos_j[None, :] < (s_pad + t_pad)  # drop padded KV
            if causal:
                mask = mask & (qpos_i[:, None] >= kpos_j[None, :])
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, v_j.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kvh, g, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
            jnp.zeros((b, kvh, g, q_chunk, hd_v), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, (kb.swapaxes(0, 1), vb.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (b, q_chunk, kvh, g, hd_v)

    chunk_fn = per_q_chunk
    if remat_chunks:
        chunk_fn = jax.checkpoint(
            per_q_chunk, policy=jax.checkpoint_policies.nothing_saveable
        )
    out = jax.lax.map(lambda args: chunk_fn(*args), (qb.swapaxes(0, 1), q_pos))
    out = out.swapaxes(0, 1).reshape(b, s_pad, h, hd_v)
    return out[:, :s]


# ---------------------------------------------------------------------------
# attention variants (train/prefill path)
# ---------------------------------------------------------------------------


def _attention(cfg: TransformerConfig, lp: Params, x, pos):
    b, s, d = x.shape
    cdt = cfg.compute_dtype
    if cfg.use_mla:
        if cfg.q_lora_rank:
            q = (x @ lp["wq_a"].astype(cdt)) @ lp["wq_b"].astype(cdt)
        else:
            q = x @ lp["wq"].astype(cdt)
        q = q.reshape(b, s, cfg.n_heads, cfg.qk_head_dim)
        q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        kv = x @ lp["wkv_a"].astype(cdt)  # (b, s, r + rope)
        latent, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
        k_rope = rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # shared head
        kvu = latent @ lp["wkv_b"].astype(cdt)  # (b, s, H*(nope+v))
        kvu = kvu.reshape(b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
        k_nope, v = kvu[..., : cfg.qk_nope_dim], kvu[..., cfg.qk_nope_dim :]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, cfg.qk_rope_dim))], -1
        )
        q = jnp.concatenate([q_nope, q_rope], -1)
        o = blockwise_attention(
            q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            remat_chunks=cfg.attn_remat,
        )
        o = o.reshape(b, s, cfg.n_heads * cfg.v_head_dim).astype(cdt)
        return o @ lp["wo"].astype(cdt)
    # GQA / MQA / MHA
    q = (x @ lp["wq"].astype(cdt)).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ lp["wk"].astype(cdt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ lp["wv"].astype(cdt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    o = blockwise_attention(
        q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        remat_chunks=cfg.attn_remat,
    )
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim).astype(cdt)
    return o @ lp["wo"].astype(cdt)


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def _dense_ffn(cfg, lp, x):
    cdt = cfg.compute_dtype
    g = _act(cfg, x @ lp["w_gate"].astype(cdt))
    u = x @ lp["w_up"].astype(cdt)
    return (g * u) @ lp["w_down"].astype(cdt)


def _moe_ffn(cfg: TransformerConfig, lp: Params, x):
    """GShard capacity dispatch with fine-grained routing groups."""
    cdt = cfg.compute_dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    gsz = min(cfg.moe_group, t)
    t_pad = -(-t // gsz) * gsz
    tokens = jnp.pad(tokens, ((0, t_pad - t), (0, 0)))
    ng = t_pad // gsz
    cap = min(max(int(gsz * k * cfg.capacity_factor / e), 1), gsz)
    xt = tokens.reshape(ng, gsz, d)

    def pin(arr, *spec):
        if cfg.moe_dp_axes:
            dp = cfg.moe_dp_axes if len(cfg.moe_dp_axes) > 1 else cfg.moe_dp_axes[0]
            resolved = [dp if a == "dp" else (cfg.moe_tp_axis or None) if a == "tp" else None for a in spec]
            return jax.lax.with_sharding_constraint(
                arr, jax.sharding.PartitionSpec(*resolved)
            )
        return arr

    logits = (xt @ lp["router"].astype(cdt)).astype(jnp.float32)  # (ng, gsz, e)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)  # (ng, gsz, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # (ng, gsz, k, e)
    onehot = pin(onehot, "dp", None, None, "tp")
    # position of each (token, choice) in its expert buffer
    pos = jnp.cumsum(onehot.reshape(ng, gsz * k, e), axis=1).reshape(ng, gsz, k, e) - 1.0
    keep = (pos < cap) * onehot
    # per-choice buffer position (gathered along e) -> no 5D (k,e,cap) tensor
    pos_k = jnp.take_along_axis(pos, top_e[..., None].astype(jnp.int32), axis=-1)[..., 0]
    cap_oh = jax.nn.one_hot(pos_k, cap, dtype=jnp.float32)  # (ng, gsz, k, cap)
    dispatch = jnp.einsum("gske,gskc->gsec", keep, cap_oh)  # (ng, gsz, e, cap)
    combine = jnp.einsum("gske,gskc->gsec", keep * top_g[..., None], cap_oh)
    dispatch = pin(dispatch, "dp", None, "tp", None)
    combine = pin(combine, "dp", None, "tp", None)

    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(cdt), xt)  # (ng, e, cap, d)
    xin = pin(xin, "dp", "tp", None, None)
    w_gate, w_up, w_down = lp["we_gate"], lp["we_up"], lp["we_down"]
    if cfg.expert_shard == "ff" and cfg.moe_dp_axes:
        # keep expert weights resident: experts over TP, d_ff over DP axes
        dp = cfg.moe_dp_axes if len(cfg.moe_dp_axes) > 1 else cfg.moe_dp_axes[0]
        wspec = jax.sharding.PartitionSpec(cfg.moe_tp_axis or None, None, dp)
        dspec = jax.sharding.PartitionSpec(cfg.moe_tp_axis or None, dp, None)
        w_gate = jax.lax.with_sharding_constraint(w_gate, wspec)
        w_up = jax.lax.with_sharding_constraint(w_up, wspec)
        w_down = jax.lax.with_sharding_constraint(w_down, dspec)
    hg = _act(cfg, jnp.einsum("gecd,edf->gecf", xin, w_gate.astype(cdt)))
    hu = jnp.einsum("gecd,edf->gecf", xin, w_up.astype(cdt))
    hout = jnp.einsum("gecf,efd->gecd", hg * hu, w_down.astype(cdt))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cdt), hout)

    if cfg.n_shared_experts:
        gsh = _act(cfg, xt @ lp["ws_gate"].astype(cdt))
        ush = xt @ lp["ws_up"].astype(cdt)
        y = y + (gsh * ush) @ lp["ws_down"].astype(cdt)
    # aux load-balance loss (GShard): mean fraction^2 per expert
    me = onehot.sum(2).mean(1)  # (ng, e) token fraction
    ce = gates.mean(1)
    aux = (me * ce).sum(-1).mean() * e
    return y.reshape(-1, d)[:t].reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _layer(cfg: TransformerConfig, lp: Params, x, pos):
    h = x + _attention(cfg, lp, rmsnorm(x, lp["ln1"]), pos)
    ff_in = rmsnorm(h, lp["ln2"])
    if cfg.is_moe:
        ff, aux = _moe_ffn(cfg, lp, ff_in)
    else:
        ff, aux = _dense_ffn(cfg, lp, ff_in), jnp.float32(0)
    return h + ff, aux


def forward(cfg: TransformerConfig, params: Params, tokens) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    cdt = cfg.compute_dtype
    b, s = tokens.shape
    x = params["embed"].astype(cdt)[tokens]  # gather; GSPMD handles vocab shard
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    layer_fn = functools.partial(_layer, cfg)
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat_policy == "nothing"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        layer_fn = jax.checkpoint(layer_fn, policy=policy)

    def scan_body(carry, lp):
        y, aux = layer_fn(lp, carry, pos)
        return y, aux

    if cfg.scan_layers:
        x, auxs = jax.lax.scan(scan_body, x, params["layers"])
        aux = auxs.sum()
    else:
        aux = jnp.float32(0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, a = layer_fn(lp, x, pos)
            aux = aux + a
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"].astype(cdt)
    if cfg.padded_vocab != cfg.vocab:  # mask pad logits out of the softmax
        pad_mask = (jnp.arange(cfg.padded_vocab) >= cfg.vocab) * jnp.asarray(
            -1e9, logits.dtype
        )
        logits = logits + pad_mask
    return logits, aux


def loss_fn(cfg: TransformerConfig, params: Params, batch) -> jax.Array:
    """Next-token cross entropy (+0.01 * MoE aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - gold).mean() + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int, dtype=None):
    """(L, B, S, cache_width) — MLA stores the compressed latent + rope key."""
    dtype = dtype or cfg.compute_dtype
    return jnp.zeros((cfg.n_layers, batch, max_seq, cfg.cache_width), dtype)


def cache_spec(fsdp=("data",), tp: str = "model") -> P:
    f = fsdp if len(fsdp) > 1 else fsdp[0]
    return P(None, f, tp, None)  # batch over FSDP axes, seq over TP


def _decode_attention(cfg: TransformerConfig, lp: Params, x, cache_l, pos):
    """One-token attention against a (B, S, cache_width) cache layer.

    Returns (out (B, 1, d), updated cache layer).  ``pos``: (B,) int32
    current positions.
    """
    b = x.shape[0]
    cdt = cfg.compute_dtype
    s_max = cache_l.shape[1]
    t_pos = jnp.arange(s_max)
    live = t_pos[None, :] <= pos[:, None]  # (B, S)

    if cfg.use_mla:
        if cfg.q_lora_rank:
            q = (x @ lp["wq_a"].astype(cdt)) @ lp["wq_b"].astype(cdt)
        else:
            q = x @ lp["wq"].astype(cdt)
        q = q.reshape(b, cfg.n_heads, cfg.qk_head_dim)
        q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
        q_rope = rope(q_rope[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        kv = (x @ lp["wkv_a"].astype(cdt))[:, None, :]  # (B,1,r+rope)
        k_rope_new = rope(
            kv[..., cfg.kv_lora_rank :][:, :, None, :], pos[:, None], cfg.rope_theta
        )[:, :, 0, :]
        new_entry = jnp.concatenate([kv[..., : cfg.kv_lora_rank], k_rope_new], -1)
        cache_l = _scatter_cache(cache_l, new_entry[:, 0], pos)
        latent = cache_l[..., : cfg.kv_lora_rank]  # (B, S, r)
        k_rope = cache_l[..., cfg.kv_lora_rank :]  # (B, S, rope)
        # absorbed scores: q_nope -> latent space via wkv_b's k-part
        wkv_b = lp["wkv_b"].astype(cdt).reshape(
            cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim
        )
        w_uk = wkv_b[..., : cfg.qk_nope_dim]  # (r, H, nope)
        w_uv = wkv_b[..., cfg.qk_nope_dim :]  # (r, H, v)
        q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
        scores = jnp.einsum("bhr,bsr->bhs", q_lat, latent.astype(jnp.float32))
        scores += jnp.einsum(
            "bhp,bsp->bhs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
        scores *= cfg.qk_head_dim**-0.5
        scores = jnp.where(live[:, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhs,bsr->bhr", w, latent.astype(jnp.float32))
        o = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads * cfg.v_head_dim).astype(cdt)
        return o @ lp["wo"].astype(cdt), cache_l

    q = (x @ lp["wq"].astype(cdt)).reshape(b, cfg.n_heads, cfg.head_dim)
    k_new = (x @ lp["wk"].astype(cdt)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    v_new = (x @ lp["wv"].astype(cdt)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    new_entry = jnp.concatenate([k_new.reshape(b, -1), v_new.reshape(b, -1)], -1)
    cache_l = _scatter_cache(cache_l, new_entry, pos)
    kc = cache_l[..., : cfg.n_kv_heads * cfg.head_dim].reshape(
        b, s_max, cfg.n_kv_heads, cfg.head_dim
    )
    vc = cache_l[..., cfg.n_kv_heads * cfg.head_dim :].reshape(
        b, s_max, cfg.n_kv_heads, cfg.head_dim
    )
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim)
    scores = (
        jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32), kc.astype(jnp.float32))
        * cfg.head_dim**-0.5
    )
    scores = jnp.where(live[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, vc.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(cdt)
    return o @ lp["wo"].astype(cdt), cache_l


def _scatter_cache(cache_l, new_entry, pos):
    """cache_l (B,S,W) <- new_entry (B,W) at per-row positions pos (B,)."""
    onehot = jax.nn.one_hot(pos, cache_l.shape[1], dtype=cache_l.dtype)  # (B,S)
    return cache_l * (1 - onehot[..., None]) + onehot[..., None] * new_entry[:, None, :]


def _decode_ffn(cfg, lp, x):
    if cfg.is_moe:
        y, _ = _moe_ffn(cfg, lp, x)
        return y
    return _dense_ffn(cfg, lp, x)


def decode_step(cfg: TransformerConfig, params: Params, cache, tokens, pos):
    """One decode step. tokens (B,) int32, pos (B,) int32 -> (logits, cache)."""
    cdt = cfg.compute_dtype
    x = params["embed"].astype(cdt)[tokens][:, None, :]  # (B,1,d)

    def body(x, inp):
        lp, cache_l = inp
        attn, cache_new = _decode_attention(
            cfg, lp, rmsnorm(x, lp["ln1"])[:, 0], cache_l, pos
        )
        h = x + attn
        h = h + _decode_ffn(cfg, lp, rmsnorm(h, lp["ln2"]))
        return h, cache_new

    if cfg.scan_layers:
        x, cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, c = body(x, (lp, cache[i]))
            caches.append(c)
        cache = jnp.stack(caches)
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(cdt))[:, 0]
    if cfg.padded_vocab != cfg.vocab:
        logits = logits + (jnp.arange(cfg.padded_vocab) >= cfg.vocab) * jnp.asarray(
            -1e9, logits.dtype
        )
    return logits, cache


def prefill(cfg: TransformerConfig, params: Params, tokens):
    """Prefill pass: full forward returning last-position logits (cache fill
    is exercised by the decode path; prefill cells measure the forward)."""
    logits, _ = forward(cfg, params, tokens)
    return logits[:, -1]
