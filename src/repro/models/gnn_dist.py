"""2D-partitioned distributed GNN message passing (the paper's SpMV pattern).

Full-batch training on ogbn-products-scale graphs (62M edges) cannot
replicate node features; the scalable layout is exactly the paper's 2D
adjacency partition (DESIGN.md §5 "2D-partitioned message passing IS the
paper's SpMV"):

* node state lives in owned chunks (rank (i,j) owns chunk q = i*C + j,
  width s) — identical geometry to core/distributed_bfs.py;
* per layer, rank (i,j) assembles the **column slice** of source features
  (TransposeVector + all-gather over rows) and the **row slice** of
  destination features (all-gather over columns), computes messages for its
  edge block, segment-reduces into row-slice partials, and an all-to-all
  over columns lands reduced aggregates at owners;
* optional **int8 payload compression** of every feature exchange
  (beyond-paper application of the paper's insight to float payloads;
  straight-through gradients, disabled for equivariance-sensitive archs).

Aggregations support sum and max so attention aggregators (GAT) run as two
passes: a max pass (softmax stability) then a fused exp-sum pass.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.csr import Partition2D
from repro.kernels.quant import ref as quant

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class Dist2DConfig:
    row_axes: tuple[str, ...] = ("data",)
    col_axis: str = "model"
    quantize_payload: bool = False  # int8 wire format for feature exchanges

    @property
    def all_axes(self) -> tuple[str, ...]:
        return self.row_axes + (self.col_axis,)


@jax.custom_vjp
def _ste_quant(x):
    """Quantize-dequantize with straight-through gradient."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % quant.GROUP
    flat = jnp.pad(flat, (0, pad))
    q, s = quant.quantize(flat)
    out = quant.dequantize(q, s)
    return out[: x.size].reshape(x.shape).astype(x.dtype)


def _ste_fwd(x):
    return _ste_quant(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_quant.defvjp(_ste_fwd, _ste_bwd)


def _wire(x, cfg: Dist2DConfig):
    return _ste_quant(x) if cfg.quantize_payload else x


def gather_col_row(h_own, part: Partition2D, cfg: Dist2DConfig):
    """Owned chunk (s, d) -> (column slice (n_c, d), row slice (n_r, d))."""
    perm = part.transpose_perm()
    h_t = jax.lax.ppermute(_wire(h_own, cfg), cfg.all_axes, perm)
    h_col = jax.lax.all_gather(h_t, cfg.row_axes, tiled=True)
    h_row = jax.lax.all_gather(_wire(h_own, cfg), cfg.col_axis, tiled=True)
    return h_col, h_row


def reduce_to_owned(partial, part: Partition2D, cfg: Dist2DConfig, op: str = "sum"):
    """Row-slice partials (n_r, d) -> owned aggregates (s, d) via all-to-all."""
    c, s = part.cols, part.chunk
    chunks = partial.reshape(c, s, -1)
    recv = jax.lax.all_to_all(_wire(chunks, cfg), cfg.col_axis, 0, 0, tiled=True)
    recv = recv.reshape(c, s, -1)
    return jnp.max(recv, axis=0) if op == "max" else jnp.sum(recv, axis=0)


def _gather_feat(h, idx, n):
    hz = jnp.concatenate([h, jnp.zeros_like(h[:1])], axis=0)
    return hz[jnp.minimum(idx, n)]


def aggregate_2d(
    h_own,
    edge_fn: Callable[[jax.Array, jax.Array], jax.Array],
    src_l,
    dst_l,
    part: Partition2D,
    cfg: Dist2DConfig,
    op: str = "sum",
    h_aux_own=None,
):
    """One 2D aggregation pass.

    ``edge_fn(h_src (m, d), h_dst (m, d)) -> messages (m, dm)``; padding
    edges (src_l == n_c) produce identity elements.  Returns owned (s, dm).
    """
    n_r, n_c, s = part.n_r, part.n_c, part.chunk
    payload = h_own if h_aux_own is None else jnp.concatenate([h_own, h_aux_own], -1)
    p_col, p_row = gather_col_row(payload, part, cfg)
    hs = _gather_feat(p_col, src_l, n_c)
    hd = _gather_feat(p_row, dst_l, n_r)
    msg = edge_fn(hs, hd)
    valid = (src_l < n_c)[:, None]
    ident = jnp.float32(0.0) if op == "sum" else jnp.float32(NEG)
    msg = jnp.where(valid, msg, ident).astype(msg.dtype)
    seg_op = jax.ops.segment_sum if op == "sum" else jax.ops.segment_max
    partial = seg_op(msg, dst_l, num_segments=n_r + 1)[:n_r]
    if op == "max":
        partial = jnp.maximum(partial, NEG)  # segment_max identity fix
    return reduce_to_owned(partial, part, cfg, op=op)


# ---------------------------------------------------------------------------
# per-arch 2D layers (forward);  params reuse the single-device inits
# ---------------------------------------------------------------------------


def _mlp(params, x):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.silu(x)
    return x


def graphcast_2d(cfg_m, params, h_own, src_l, dst_l, part, dcfg):
    """Interaction-network stack, sum aggregation (edge state omitted in the
    distributed variant: messages recomputed per layer — remat-style)."""
    h = _mlp(params["encoder"], h_own)
    d = h.shape[-1]
    for lyr in params["layers"]:
        def edge_fn(hs, hd, lyr=lyr):
            e = _mlp(lyr["edge"], jnp.concatenate([jnp.zeros_like(hs), hs, hd], -1))
            return e

        agg = aggregate_2d(h, edge_fn, src_l, dst_l, part, dcfg, op="sum")
        h = h + _mlp(lyr["node"], jnp.concatenate([h, agg], -1))
    return _mlp(params["decoder"], h)


def gat_2d(cfg_m, params, h_own, src_l, dst_l, part, dcfg):
    """GAT: max pass (stability) then fused exp-sum pass per layer."""
    h = h_own
    for li, lyr in enumerate(params["layers"]):
        heads, d_out = lyr["w"].shape[0], lyr["w"].shape[2]
        z = jnp.einsum("nd,hdo->nho", h, lyr["w"]).reshape(h.shape[0], -1)

        def logits_fn(zs, zd, lyr=lyr, heads=heads, d_out=d_out):
            zs = zs.reshape(-1, heads, d_out)
            zd = zd.reshape(-1, heads, d_out)
            lg = jnp.einsum("mho,ho->mh", zs, lyr["a_src"]) + jnp.einsum(
                "mho,ho->mh", zd, lyr["a_dst"]
            )
            return jax.nn.leaky_relu(lg, 0.2)

        mx = aggregate_2d(z, logits_fn, src_l, dst_l, part, dcfg, op="max")

        def expsum_fn(payload_s, payload_d, lyr=lyr, heads=heads, d_out=d_out):
            zs = payload_s[:, : heads * d_out].reshape(-1, heads, d_out)
            zd = payload_d[:, : heads * d_out].reshape(-1, heads, d_out)
            mxd = payload_d[:, heads * d_out : heads * d_out + heads]
            lg = jnp.einsum("mho,ho->mh", zs, lyr["a_src"]) + jnp.einsum(
                "mho,ho->mh", zd, lyr["a_dst"]
            )
            e = jnp.exp(jax.nn.leaky_relu(lg, 0.2) - mxd)  # (m, h)
            num = (e[..., None] * zs).reshape(e.shape[0], -1)
            return jnp.concatenate([num, e], -1)

        agg = aggregate_2d(
            z, expsum_fn, src_l, dst_l, part, dcfg, op="sum", h_aux_own=mx
        )
        num = agg[:, : heads * d_out].reshape(-1, heads, d_out)
        den = agg[:, heads * d_out :][:, :, None]
        h = (num / jnp.maximum(den, 1e-16)).reshape(h.shape[0], -1)
        if li < len(params["layers"]) - 1:
            h = jax.nn.elu(h)
    return h


def egnn_2d(cfg_m, params, h_own, pos_own, src_l, dst_l, part, dcfg):
    """EGNN: payload = [h, x]; messages and coordinate deltas in one pass."""
    h = _mlp(params["embed"], h_own)
    x = pos_own
    d = h.shape[-1]
    for lyr in params["layers"]:
        def edge_fn(ps, pd, lyr=lyr, d=d):
            hs, xs = ps[:, :d], ps[:, d : d + 3]
            hd, xd = pd[:, :d], pd[:, d : d + 3]
            diff = xd - xs
            d2 = jnp.sum(diff * diff, -1, keepdims=True)
            m = _mlp(lyr["edge"], jnp.concatenate([hs, hd, d2], -1))
            w = jnp.tanh(_mlp(lyr["coord"], m))
            return jnp.concatenate([m, -diff * w, jnp.ones_like(d2)], -1)

        agg = aggregate_2d(
            h, edge_fn, src_l, dst_l, part, dcfg, op="sum", h_aux_own=x
        )
        m_agg, dx, deg = agg[:, :d], agg[:, d : d + 3], agg[:, d + 3 :]
        x = x + dx / jnp.maximum(deg, 1.0)
        h = h + _mlp(lyr["node"], jnp.concatenate([h, m_agg], -1))
    return _mlp(params["out"], h)


def nequip_2d(cfg_m, params, h_own, pos_own, src_l, dst_l, part, dcfg):
    """NequIP: flatten l<=2 irreps into the payload (13c floats/node).

    Payload quantization is force-disabled — lossy wire formats break exact
    equivariance (DESIGN.md §Arch-applicability)."""
    from repro.models import irreps as ir

    dcfg = dataclasses.replace(dcfg, quantize_payload=False)
    c = cfg_m.d_hidden
    n = h_own.shape[0]
    s_f = _mlp(params["embed"], h_own)
    v_f = jnp.zeros((n, c, 3))
    t_f = jnp.zeros((n, c, 3, 3))
    for lyr in params["layers"]:
        payload = jnp.concatenate(
            [s_f, v_f.reshape(n, -1), t_f.reshape(n, -1), pos_own], -1
        )

        def edge_fn(ps, pd, lyr=lyr, c=c):
            m = ps.shape[0]
            hs_s = ps[:, :c]
            hs_v = ps[:, c : 4 * c].reshape(m, c, 3)
            hs_t = ps[:, 4 * c : 13 * c].reshape(m, c, 3, 3)
            xs = ps[:, 13 * c :]
            xd = pd[:, 13 * c :]
            disp = xd - xs
            r = jnp.sqrt(jnp.sum(disp * disp, -1) + 1e-12)
            rhat = disp / r[:, None]
            y1, y2 = ir.sph_l1(rhat), ir.sph_l2(rhat)
            rbf = ir.bessel_rbf(r, cfg_m.n_rbf, cfg_m.cutoff)
            w = _mlp(lyr["radial"], rbf)
            w0, w1, w2 = w[:, :c], w[:, c : 2 * c], w[:, 2 * c :]
            m_s = w0 * (hs_s + ir.p_vv_s(hs_v, y1[:, None, :]))
            m_v = w1[..., None] * (
                hs_s[..., None] * y1[:, None, :] + hs_v + ir.p_tv_v(hs_t, y1[:, None, :])
            )
            m_t = w2[..., None, None] * (
                hs_s[..., None, None] * y2[:, None] + ir.p_vv_t(hs_v, y1[:, None, :]) + hs_t
            )
            return jnp.concatenate(
                [m_s, m_v.reshape(m, -1), m_t.reshape(m, -1)], -1
            )

        agg = aggregate_2d(payload, edge_fn, src_l, dst_l, part, dcfg, op="sum")
        a = ir.Irreps(
            s=agg[:, :c],
            v=agg[:, c : 4 * c].reshape(n, c, 3),
            t=agg[:, 4 * c :].reshape(n, c, 3, 3),
        )
        mixed = ir.linear(a, lyr["w_s"], lyr["w_v"], lyr["w_t"])
        gates = _mlp(lyr["gate"], mixed.s)
        out = ir.gate(mixed, gates[:, :c], gates[:, c:])
        s_f, v_f, t_f = s_f + out.s, v_f + out.v, t_f + out.t
    return _mlp(params["readout"], s_f)


# ---------------------------------------------------------------------------
# shard_map train-step builder
# ---------------------------------------------------------------------------

_FWD_2D = {
    "graphcast": graphcast_2d,
    "gat-cora": gat_2d,
    "egnn": egnn_2d,
    "nequip": nequip_2d,
}


def build_2d_train_step(
    mesh: Mesh,
    model_cfg,
    part: Partition2D,
    e_cap: int,
    dcfg: Dist2DConfig | None = None,
    n_classes: int = 16,
):
    """Returns jit'd fn(params, nf, pos, src_l, dst_l, targets) -> (loss, grads).

    nf/pos/targets are owner-chunk sharded (R, C, s, .); edge blocks are
    (R, C, e_cap) with local indices, as produced by core.csr.partition_2d.
    """
    dcfg = dcfg or Dist2DConfig(
        row_axes=tuple(mesh.axis_names[:-1]), col_axis=mesh.axis_names[-1]
    )
    fwd = _FWD_2D[model_cfg.name]
    needs_pos = model_cfg.name in ("egnn", "nequip")

    def local(params, nf, pos, src_l, dst_l, targets):
        nf = nf.reshape(part.chunk, -1)
        pos = pos.reshape(part.chunk, -1)
        src_l = src_l.reshape(-1)
        dst_l = dst_l.reshape(-1)
        targets = targets.reshape(part.chunk)

        def loss_fn(p):
            if needs_pos:
                out = fwd(model_cfg, p, nf, pos, src_l, dst_l, part, dcfg)
            else:
                out = fwd(model_cfg, p, nf, src_l, dst_l, part, dcfg)
            logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, targets[:, None], -1)[:, 0]
            return jax.lax.pmean(nll.mean(), dcfg.all_axes)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, dcfg.all_axes), grads)
        return loss, grads

    own = P(*dcfg.row_axes, dcfg.col_axis, None)
    own_flat = P(*dcfg.row_axes, dcfg.col_axis)
    in_specs = (P(), own, own, own, own, own_flat)
    mapped = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(mapped), in_specs
