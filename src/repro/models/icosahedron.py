"""Refined icosahedral multimesh for GraphCast (numpy, host-side).

GraphCast's processor runs on the union of edges from every refinement level
("multimesh").  Refinement r splits each triangle into 4; refinement 6 gives
40,962 nodes and 81,920 faces.
"""

from __future__ import annotations

import numpy as np


def icosahedron() -> tuple[np.ndarray, np.ndarray]:
    """Unit icosahedron: (12, 3) vertices, (20, 3) faces."""
    phi = (1 + 5**0.5) / 2
    v = np.array(
        [
            [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
            [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
            [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1],
        ],
        dtype=np.float64,
    )
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    f = np.array(
        [
            [0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
            [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
            [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
            [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1],
        ],
        dtype=np.int64,
    )
    return v, f


def subdivide(verts: np.ndarray, faces: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One 4-way triangle subdivision, projecting midpoints to the sphere."""
    edge_mid: dict[tuple[int, int], int] = {}
    verts = list(verts)

    def midpoint(a: int, b: int) -> int:
        key = (min(a, b), max(a, b))
        if key not in edge_mid:
            m = verts[a] + verts[b]
            m = m / np.linalg.norm(m)
            edge_mid[key] = len(verts)
            verts.append(m)
        return edge_mid[key]

    new_faces = []
    for a, b, c in faces:
        ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
        new_faces += [[a, ab, ca], [b, bc, ab], [c, ca, bc], [ab, bc, ca]]
    return np.asarray(verts), np.asarray(new_faces, dtype=np.int64)


def faces_to_edges(faces: np.ndarray) -> np.ndarray:
    """Unique directed edges (both directions) of a triangle mesh."""
    e = np.concatenate([faces[:, [0, 1]], faces[:, [1, 2]], faces[:, [2, 0]]])
    e = np.concatenate([e, e[:, ::-1]])
    return np.unique(e, axis=0)


def multimesh(refinement: int) -> tuple[np.ndarray, np.ndarray]:
    """(verts (n,3), edges (m,2)) — union of edges over all refinement levels."""
    verts, faces = icosahedron()
    all_edges = [faces_to_edges(faces)]
    for _ in range(refinement):
        verts, faces = subdivide(verts, faces)
        all_edges.append(faces_to_edges(faces))
    edges = np.unique(np.concatenate(all_edges), axis=0)
    return verts, edges
