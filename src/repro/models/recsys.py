"""AutoInt recommender (arXiv:1810.11921) with a hand-built EmbeddingBag.

JAX has no ``nn.EmbeddingBag``: lookup is ``jnp.take`` on a fused table +
``jax.ops.segment_sum``-style masked pooling over per-bag value slots —
built here as a first-class substrate (kernel taxonomy §RecSys).  The
embedding tables are the hot path: 39 sparse fields with multi-million-row
tables (Criteo-like cardinalities), row-sharded across the whole mesh.

Paths:
* ``forward``          — CTR scoring: embeddings -> 3 self-attention
                         interaction layers (2 heads, d=32) -> MLP -> logit.
* ``retrieval_scores`` — one query against N candidate items: the user tower
                         runs once; candidates scored by one (N, d) @ (d,)
                         matvec (batched dot, not a loop).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]

# Criteo-like table sizes cycled over the 39 sparse fields (public Criteo-1TB
# cardinalities span 10..~200M; this mix keeps the fused table ~120M rows).
_TABLE_SIZES = (
    40_000_000, 10_000_000, 4_000_000, 2_000_000, 1_000_000, 500_000,
    200_000, 100_000, 50_000, 10_000, 2_000, 500, 128,
)


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    mlp_dims: tuple[int, ...] = (256, 128)
    table_sizes: tuple[int, ...] = ()
    # int8 row-quantized embedding table (per-row scale) — the paper's
    # compression insight applied to the lookup payload (§Perf): 4x less
    # table memory AND 4x fewer gather bytes on the wire.
    table_quant: bool = False

    def resolved_tables(self) -> tuple[int, ...]:
        if self.table_sizes:
            sizes = list(self.table_sizes)
        else:
            sizes = [_TABLE_SIZES[i % len(_TABLE_SIZES)] for i in range(self.n_sparse)]
        # pad the last table so the fused table row count shards evenly on
        # any mesh up to 4096 chips (row-sharded lookup requires it)
        total = sum(sizes)
        sizes[-1] += -total % 4096
        return tuple(sizes)

    @property
    def total_rows(self) -> int:
        return sum(self.resolved_tables())

    @property
    def d_interact(self) -> int:
        return self.n_heads * self.d_attn

    def n_params(self) -> int:
        d, da, h = self.embed_dim, self.d_attn, self.n_heads
        n = self.total_rows * d
        d_prev = d
        for _ in range(self.n_attn_layers):
            n += 3 * h * d_prev * da + d_prev * h * da
            d_prev = h * da
        dims = (self.n_sparse * d_prev,) + self.mlp_dims + (1,)
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        n += d_prev * d  # retrieval projection
        return n


def field_offsets(cfg: AutoIntConfig) -> jnp.ndarray:
    import numpy as np

    sizes = np.asarray(cfg.resolved_tables(), np.int64)
    offs = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return jnp.asarray(offs, jnp.int32 if cfg.total_rows < 2**31 else jnp.int64)


def init_params(cfg: AutoIntConfig, key, table_dtype=jnp.float32) -> Params:
    ks = iter(jax.random.split(key, 8 + 4 * cfg.n_attn_layers))
    d, da, h = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    layers = []
    d_prev = d
    for _ in range(cfg.n_attn_layers):
        layers.append(
            {
                "wq": jax.random.normal(next(ks), (h, d_prev, da)) / d_prev**0.5,
                "wk": jax.random.normal(next(ks), (h, d_prev, da)) / d_prev**0.5,
                "wv": jax.random.normal(next(ks), (h, d_prev, da)) / d_prev**0.5,
                "wres": jax.random.normal(next(ks), (d_prev, h * da)) / d_prev**0.5,
            }
        )
        d_prev = h * da
    dims = (cfg.n_sparse * d_prev,) + cfg.mlp_dims + (1,)
    mlp = [
        {
            "w": jax.random.normal(next(ks), (a, b)) / a**0.5,
            "b": jnp.zeros((b,)),
        }
        for a, b in zip(dims[:-1], dims[1:])
    ]
    if cfg.table_quant:
        raw = jax.random.normal(next(ks), (cfg.total_rows, d)) * 0.01
        scale = jnp.maximum(jnp.max(jnp.abs(raw), axis=1), 1e-8) / 127.0
        table = jnp.clip(jnp.round(raw / scale[:, None]), -127, 127).astype(jnp.int8)
        extra = {"table": table, "table_scale": scale.astype(jnp.float32)}
    else:
        extra = {
            "table": (jax.random.normal(next(ks), (cfg.total_rows, d)) * 0.01).astype(
                table_dtype
            )
        }
    return {
        **extra,
        "attn": layers,
        "mlp": mlp,
        "w_user": jax.random.normal(next(ks), (d_prev, d)) / d_prev**0.5,
    }


def param_specs(cfg: AutoIntConfig, fsdp=("data",), tp: str = "model"):
    """Embedding table row-sharded over *all* mesh axes (the DLRM layout);
    the dense interaction/MLP params are tiny and replicated."""
    all_axes = tuple(fsdp) + (tp,)
    return {
        "table": P(all_axes, None),
        "attn": [
            {"wq": P(None), "wk": P(None), "wv": P(None), "wres": P(None)}
            for _ in range(cfg.n_attn_layers)
        ],
        "mlp": [{"w": P(None), "b": P(None)} for _ in range(len(cfg.mlp_dims) + 1)],
        "w_user": P(None),
    }


# ---------------------------------------------------------------------------
# EmbeddingBag: take + masked segment pooling
# ---------------------------------------------------------------------------


def embedding_bag(table, ids, offsets=None, mode: str = "sum"):
    """torch.nn.EmbeddingBag equivalent on a fused table.

    Args:
      table: (rows, d).
      ids: (B, F) single-valued, or (B, F, K) multi-valued with -1 padding.
      offsets: optional (F,) per-field base offsets into the fused table.
    Returns (B, F, d) pooled embeddings.
    """
    if offsets is not None:
        off = offsets.reshape((1, -1) + (1,) * (ids.ndim - 2)).astype(ids.dtype)
        ids = jnp.where(ids >= 0, ids + off, ids)
    if ids.ndim == 2:
        return jnp.take(table, jnp.maximum(ids, 0), axis=0)
    b, f, k = ids.shape
    valid = (ids >= 0)[..., None]
    emb = jnp.take(table, jnp.maximum(ids, 0).reshape(-1), axis=0).reshape(b, f, k, -1)
    pooled = (emb * valid).sum(axis=2)
    if mode == "mean":
        pooled = pooled / jnp.maximum(valid.sum(axis=2), 1)
    return pooled


# ---------------------------------------------------------------------------
# AutoInt forward paths
# ---------------------------------------------------------------------------


def _interact(cfg: AutoIntConfig, params: Params, emb):
    """emb (B, F, d) -> (B, F, h*da) via stacked self-attention layers."""
    x = emb
    for lyr in params["attn"]:
        q = jnp.einsum("bfd,hde->bhfe", x, lyr["wq"])
        k = jnp.einsum("bfd,hde->bhfe", x, lyr["wk"])
        v = jnp.einsum("bfd,hde->bhfe", x, lyr["wv"])
        scores = jnp.einsum("bhfe,bhge->bhfg", q, k) / cfg.d_attn**0.5
        w = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhfg,bhge->bhfe", w, v)
        o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], -1)
        x = jax.nn.relu(o + x @ lyr["wres"])
    return x


def _lookup(cfg: AutoIntConfig, params: Params, ids):
    """Embedding lookup; dequantizes after the (int8) gather when quantized."""
    emb = embedding_bag(params["table"], ids, offsets=field_offsets(cfg))
    if cfg.table_quant:
        offs = field_offsets(cfg)
        flat = jnp.where(ids >= 0, ids + offs[None, :].astype(ids.dtype), 0)
        scale = jnp.take(params["table_scale"], flat, axis=0)  # (B, F)
        emb = emb.astype(jnp.float32) * scale[..., None]
    return emb


def forward(cfg: AutoIntConfig, params: Params, ids) -> jax.Array:
    """ids (B, F) int per-field local indices -> CTR logits (B,)."""
    emb = _lookup(cfg, params, ids)
    x = _interact(cfg, params, emb)
    flat = x.reshape(x.shape[0], -1)
    for i, lyr in enumerate(params["mlp"]):
        flat = flat @ lyr["w"] + lyr["b"]
        if i < len(params["mlp"]) - 1:
            flat = jax.nn.relu(flat)
    return flat[:, 0]


def loss_fn(cfg: AutoIntConfig, params: Params, batch) -> jax.Array:
    """Binary cross-entropy on click labels (numerically stable form)."""
    logits = forward(cfg, params, batch["ids"])
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def user_vector(cfg: AutoIntConfig, params: Params, ids) -> jax.Array:
    """(B, F) query features -> (B, embed_dim) user vectors (two-tower head)."""
    emb = _lookup(cfg, params, ids)
    x = _interact(cfg, params, emb)  # (B, F, d_interact)
    return x.mean(axis=1) @ params["w_user"]  # (B, embed_dim)


def retrieval_scores(cfg: AutoIntConfig, params: Params, ids, cand_ids) -> jax.Array:
    """Score one query (1, F) against N candidates of the last sparse field.

    The user tower runs once; candidate scoring is a single (N, d) @ (d,)
    matvec against the candidate field's embedding rows."""
    uv = user_vector(cfg, params, ids)[0]  # (d,)
    last_off = field_offsets(cfg)[-1]
    rows = cand_ids + last_off.astype(cand_ids.dtype)
    item_emb = jnp.take(params["table"], rows, axis=0)
    if cfg.table_quant:
        item_emb = item_emb.astype(jnp.float32) * jnp.take(
            params["table_scale"], rows, axis=0
        )[:, None]
    return item_emb @ uv
