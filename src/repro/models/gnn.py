"""Graph neural networks via segment_sum message passing (no sparse formats).

JAX has no CSR/EmbeddingBag/SpMM primitives: every aggregator here is a
gather over an edge index followed by ``jax.ops.segment_{sum,max,min}`` over
destinations — this IS the system (see kernel taxonomy §GNN).  Padding edges
use the sentinel (src = dst = n) and fall into segment n, which is dropped.

Architectures (assigned pool):
* ``graphcast``  — encode-process-decode stack of interaction networks
                   (edge MLP + node MLP + residual), sum aggregation.
* ``gat-cora``   — multi-head attention aggregation (SDDMM -> edge softmax
                   -> SpMM, all as segment ops).
* ``egnn``       — E(n)-equivariant: messages from invariants (h_i, h_j,
                   |x_i - x_j|^2), coordinate updates along displacements.
* ``nequip``     — E(3)-equivariant l<=2 tensor-product convolutions
                   (see repro.models.irreps).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import irreps as ir

Params = dict[str, Any]


class Graph(NamedTuple):
    """Static-shape graph batch. Padding edges: src = dst = n."""

    nf: jax.Array  # (n, d_in) node features
    src: jax.Array  # (m,) int32
    dst: jax.Array  # (m,) int32
    pos: jax.Array | None = None  # (n, 3) coordinates (EGNN / NequIP)

    @property
    def n(self) -> int:
        return self.nf.shape[0]

    @property
    def m(self) -> int:
        return self.src.shape[0]


def seg_sum(vals, seg, n):
    return jax.ops.segment_sum(vals, seg, num_segments=n + 1)[:n]


def seg_max(vals, seg, n):
    return jax.ops.segment_max(vals, seg, num_segments=n + 1)[:n]


def segment_softmax(logits, seg, n):
    """Numerically stable softmax over edges grouped by destination."""
    mx = seg_max(logits, seg, n)
    mx_full = jnp.concatenate([mx, jnp.zeros_like(mx[:1])])
    e = jnp.exp(logits - mx_full[jnp.minimum(seg, n)])
    denom = seg_sum(e, seg, n)
    denom_full = jnp.concatenate([denom, jnp.ones_like(denom[:1])])
    return e / jnp.maximum(denom_full[jnp.minimum(seg, n)], 1e-16)


def _mlp_params(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": (jax.random.normal(k, (a, b)) / a**0.5).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def _mlp(params, x):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.silu(x)
    return x


def _gather(h, idx, n):
    """Sentinel-safe node gather (idx == n -> zeros)."""
    hz = jnp.concatenate([h, jnp.zeros_like(h[:1])], axis=0)
    return hz[jnp.minimum(idx, n)]


# ---------------------------------------------------------------------------
# GraphCast-style interaction networks (encode-process-decode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    d_in: int = 227  # n_vars
    d_out: int = 227
    mesh_refinement: int = 6
    edge_state: bool = True  # persistent edge features (off in the 2D path)


def init_graphcast(cfg: GraphCastConfig, key) -> Params:
    ks = jax.random.split(key, 2 * cfg.n_layers + 2)
    d = cfg.d_hidden
    return {
        "encoder": _mlp_params(ks[0], (cfg.d_in, d, d)),
        "layers": [
            {
                "edge": _mlp_params(ks[2 * i + 1], (3 * d, d, d)),
                "node": _mlp_params(ks[2 * i + 2], (2 * d, d, d)),
            }
            for i in range(cfg.n_layers)
        ],
        "decoder": _mlp_params(ks[-1], (d, d, cfg.d_out)),
    }


def graphcast_forward(cfg: GraphCastConfig, params: Params, g: Graph) -> jax.Array:
    n = g.n
    h = _mlp(params["encoder"], g.nf)
    ef = jnp.zeros((g.m, cfg.d_hidden), h.dtype)
    valid = (g.src < n)[:, None]
    for lyr in params["layers"]:
        hs, hd = _gather(h, g.src, n), _gather(h, g.dst, n)
        msg = _mlp(lyr["edge"], jnp.concatenate([ef, hs, hd], -1)) * valid
        if cfg.edge_state:
            ef = ef + msg
            msg = ef
        agg = seg_sum(msg, g.dst, n)
        h = h + _mlp(lyr["node"], jnp.concatenate([h, agg], -1))
    return _mlp(params["decoder"], h)


# ---------------------------------------------------------------------------
# GAT (attention aggregation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8  # per head
    n_heads: int = 8
    d_in: int = 1433
    d_out: int = 7
    negative_slope: float = 0.2


def init_gat(cfg: GATConfig, key) -> Params:
    ks = jax.random.split(key, 3 * cfg.n_layers)
    layers = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.d_out if last else cfg.d_hidden
        layers.append(
            {
                "w": (
                    jax.random.normal(ks[3 * i], (heads, d_prev, d_out)) / d_prev**0.5
                ).astype(jnp.float32),
                "a_src": jax.random.normal(ks[3 * i + 1], (heads, d_out)) * 0.1,
                "a_dst": jax.random.normal(ks[3 * i + 2], (heads, d_out)) * 0.1,
            }
        )
        d_prev = heads * d_out
    return {"layers": layers}


def gat_forward(cfg: GATConfig, params: Params, g: Graph) -> jax.Array:
    n, h = g.n, g.nf
    for i, lyr in enumerate(params["layers"]):
        heads = lyr["w"].shape[0]
        z = jnp.einsum("nd,hdo->nho", h, lyr["w"])  # (n, heads, d_out)
        # SDDMM: per-edge attention logits
        zs, zd = _gather(z, g.src, n), _gather(z, g.dst, n)
        logits = jnp.einsum("mho,ho->mh", zs, lyr["a_src"]) + jnp.einsum(
            "mho,ho->mh", zd, lyr["a_dst"]
        )
        logits = jax.nn.leaky_relu(logits, cfg.negative_slope)
        logits = jnp.where((g.src < n)[:, None], logits, -1e30)
        alpha = jax.vmap(lambda l: segment_softmax(l, g.dst, n), 1, 1)(logits)
        msg = alpha[..., None] * zs  # (m, heads, d_out)
        agg = seg_sum(msg.reshape(g.m, -1), g.dst, n).reshape(n, heads, -1)
        h = agg.reshape(n, -1)
        if i < len(params["layers"]) - 1:
            h = jax.nn.elu(h)
    return h


# ---------------------------------------------------------------------------
# EGNN (E(n)-equivariant)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    d_out: int = 16


def init_egnn(cfg: EGNNConfig, key) -> Params:
    ks = jax.random.split(key, 3 * cfg.n_layers + 2)
    d = cfg.d_hidden
    return {
        "embed": _mlp_params(ks[0], (cfg.d_in, d)),
        "layers": [
            {
                "edge": _mlp_params(ks[3 * i + 1], (2 * d + 1, d, d)),
                "coord": _mlp_params(ks[3 * i + 2], (d, d, 1)),
                "node": _mlp_params(ks[3 * i + 3], (2 * d, d, d)),
            }
            for i in range(cfg.n_layers)
        ],
        "out": _mlp_params(ks[-1], (d, cfg.d_out)),
    }


def egnn_forward(cfg: EGNNConfig, params: Params, g: Graph):
    """Returns (node outputs (n, d_out), updated coordinates (n, 3))."""
    assert g.pos is not None
    n = g.n
    h = _mlp(params["embed"], g.nf)
    x = g.pos
    valid = (g.src < n)[:, None]
    for lyr in params["layers"]:
        hs, hd = _gather(h, g.src, n), _gather(h, g.dst, n)
        xs, xd = _gather(x, g.src, n), _gather(x, g.dst, n)
        diff = xd - xs
        d2 = jnp.sum(diff * diff, -1, keepdims=True)
        m_ij = _mlp(lyr["edge"], jnp.concatenate([hs, hd, d2], -1)) * valid
        # E(n) coordinate update: x_i += mean_j (x_i - x_j) * phi_x(m_ij)
        w = jnp.tanh(_mlp(lyr["coord"], m_ij))  # bounded for stability
        deg = jnp.maximum(seg_sum(valid.astype(x.dtype), g.dst, n), 1.0)
        x = x + seg_sum(-diff * w * valid, g.dst, n) / deg
        agg = seg_sum(m_ij, g.dst, n)
        h = h + _mlp(lyr["node"], jnp.concatenate([h, agg], -1))
    return _mlp(params["out"], h), x


# ---------------------------------------------------------------------------
# NequIP (E(3)-equivariant tensor-product convolutions, l <= 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32  # channels per l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16  # species embedding width
    d_out: int = 1  # per-atom energy


def init_nequip(cfg: NequIPConfig, key) -> Params:
    c = cfg.d_hidden
    ks = jax.random.split(key, 8 * cfg.n_layers + 2)
    k = iter(ks)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                # radial MLP -> per-path weights (3 paths x channels)
                "radial": _mlp_params(next(k), (cfg.n_rbf, c, 3 * c)),
                "w_s": jax.random.normal(next(k), (c, c)) / c**0.5,
                "w_v": jax.random.normal(next(k), (c, c)) / c**0.5,
                "w_t": jax.random.normal(next(k), (c, c)) / c**0.5,
                "gate": _mlp_params(next(k), (c, 2 * c)),
            }
        )
    return {
        "embed": _mlp_params(next(k), (cfg.d_in, c)),
        "layers": layers,
        "readout": _mlp_params(next(k), (c, c, cfg.d_out)),
    }


def nequip_forward(cfg: NequIPConfig, params: Params, g: Graph) -> jax.Array:
    """Per-node scalar outputs (invariant); internal features are l<=2."""
    assert g.pos is not None
    n, c = g.n, cfg.d_hidden
    feats = ir.Irreps(
        s=_mlp(params["embed"], g.nf),
        v=jnp.zeros((n, c, 3)),
        t=jnp.zeros((n, c, 3, 3)),
    )
    valid_e = g.src < n
    xs, xd = _gather(g.pos, g.src, n), _gather(g.pos, g.dst, n)
    disp = xd - xs
    r = jnp.sqrt(jnp.sum(disp * disp, -1) + 1e-12)
    rhat = disp / r[:, None]
    y1 = ir.sph_l1(rhat)  # (m, 3)
    y2 = ir.sph_l2(rhat)  # (m, 3, 3)
    rbf = ir.bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * valid_e[:, None]

    for lyr in params["layers"]:
        w = _mlp(lyr["radial"], rbf)  # (m, 3c)
        w0, w1, w2 = w[:, :c], w[:, c : 2 * c], w[:, 2 * c :]
        hs = ir.Irreps(
            s=_gather(feats.s, g.src, n),
            v=_gather(feats.v.reshape(n, -1), g.src, n).reshape(-1, c, 3),
            t=_gather(feats.t.reshape(n, -1), g.src, n).reshape(-1, c, 3, 3),
        )
        # tensor-product messages: neighbor features (x) SH(rhat), radial-weighted
        m_s = w0 * (hs.s + ir.p_vv_s(hs.v, y1[:, None, :]))  # 0x0->0, 1x1->0
        m_v = w1[..., None] * (
            hs.s[..., None] * y1[:, None, :]  # 0x1->1
            + hs.v  # 0(r)x1 identity path
            + ir.p_tv_v(hs.t, y1[:, None, :])  # 2x1->1
        )
        m_t = w2[..., None, None] * (
            hs.s[..., None, None] * y2[:, None]  # 0x2->2
            + ir.p_vv_t(hs.v, y1[:, None, :])  # 1x1->2
            + hs.t  # identity path
        )
        agg = ir.Irreps(
            s=seg_sum(m_s, g.dst, n),
            v=seg_sum(m_v.reshape(g.m, -1), g.dst, n).reshape(n, c, 3),
            t=seg_sum(m_t.reshape(g.m, -1), g.dst, n).reshape(n, c, 3, 3),
        )
        mixed = ir.linear(agg, lyr["w_s"], lyr["w_v"], lyr["w_t"])
        gates = _mlp(lyr["gate"], mixed.s)
        out = ir.gate(mixed, gates[:, :c], gates[:, c:])
        feats = ir.Irreps(
            s=feats.s + out.s, v=feats.v + out.v, t=feats.t + out.t
        )
    return _mlp(params["readout"], feats.s)


# ---------------------------------------------------------------------------
# unified facade used by configs / dryrun
# ---------------------------------------------------------------------------


def init(cfg, key) -> Params:
    if isinstance(cfg, GraphCastConfig):
        return init_graphcast(cfg, key)
    if isinstance(cfg, GATConfig):
        return init_gat(cfg, key)
    if isinstance(cfg, EGNNConfig):
        return init_egnn(cfg, key)
    if isinstance(cfg, NequIPConfig):
        return init_nequip(cfg, key)
    raise TypeError(type(cfg))


def forward(cfg, params: Params, g: Graph) -> jax.Array:
    if isinstance(cfg, GraphCastConfig):
        return graphcast_forward(cfg, params, g)
    if isinstance(cfg, GATConfig):
        return gat_forward(cfg, params, g)
    if isinstance(cfg, EGNNConfig):
        return egnn_forward(cfg, params, g)[0]
    if isinstance(cfg, NequIPConfig):
        return nequip_forward(cfg, params, g)
    raise TypeError(type(cfg))


def loss_fn(cfg, params: Params, batch) -> jax.Array:
    """Node-level loss: cross-entropy when integer targets, else MSE."""
    g: Graph = batch["graph"]
    out = forward(cfg, params, g)
    tgt = batch["targets"]
    mask = batch.get("mask")
    if jnp.issubdtype(tgt.dtype, jnp.integer):
        logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tgt[:, None], -1)[:, 0]
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)
        return nll.mean()
    err = (out.astype(jnp.float32) - tgt) ** 2
    if mask is not None:
        return jnp.sum(err * mask[:, None]) / jnp.maximum(mask.sum() * err.shape[-1], 1)
    return err.mean()
