"""Model zoo: every assigned architecture family as composable JAX modules.

* :mod:`repro.models.transformer` — decoder LMs (dense / GQA / MQA / MLA /
  fine-grained MoE), scan-over-layers, blockwise attention, KV-cache serving.
* :mod:`repro.models.gnn` — segment_sum message passing: GCN-style sum
  aggregation, GAT attention aggregation, EGNN E(n) coordinate updates,
  NequIP-style l<=2 tensor products, GraphCast encode-process-decode.
* :mod:`repro.models.recsys` — AutoInt: EmbeddingBag (take + segment_sum)
  over sharded tables + self-attention feature interaction.
"""
