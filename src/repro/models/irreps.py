"""E(3)-equivariant feature algebra up to l=2, in the Cartesian basis.

NequIP composes features that transform as irreps of O(3).  Rather than a
spherical-harmonic/Clebsch-Gordan machine, we carry the l<=2 content in
Cartesian form (exactly equivalent for l<=2, and MXU-friendly):

* l=0: scalars  (n, c)
* l=1: vectors  (n, c, 3)
* l=2: traceless symmetric matrices (n, c, 3, 3)  (5 dof embedded in 9)

Tensor-product paths are the classical vector-algebra identities: dot,
cross, symmetric-traceless outer product, matrix-vector action, Frobenius
contraction.  Equivariance is exact in exact arithmetic and verified by
rotation tests (tests/test_models.py::test_nequip_equivariance).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

EYE3 = jnp.eye(3)


class Irreps(NamedTuple):
    """A (scalars, vectors, tensors) feature triple; any member may be None."""

    s: jax.Array | None  # (n, c0)
    v: jax.Array | None  # (n, c1, 3)
    t: jax.Array | None  # (n, c2, 3, 3)

    def map(self, fn):
        return Irreps(*(None if x is None else fn(x) for x in self))


def sph_l1(rhat: jax.Array) -> jax.Array:
    """(m, 3) unit displacement -> l=1 'spherical harmonic' (itself)."""
    return rhat


def sph_l2(rhat: jax.Array) -> jax.Array:
    """(m, 3) -> (m, 3, 3) traceless symmetric outer product."""
    outer = rhat[:, :, None] * rhat[:, None, :]
    return outer - EYE3 / 3.0


def traceless_sym(m: jax.Array) -> jax.Array:
    """Project (..., 3, 3) onto its traceless symmetric part (l=2)."""
    sym = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)[..., None, None]
    return sym - tr * EYE3 / 3.0


# --- product paths (each output is an irrep of the stated l) ---------------


def p_vv_s(a: jax.Array, b: jax.Array) -> jax.Array:
    """1 (x) 1 -> 0 : dot product. (., c, 3) x (., c, 3) -> (., c)."""
    return jnp.sum(a * b, axis=-1)


def p_vv_v(a: jax.Array, b: jax.Array) -> jax.Array:
    """1 (x) 1 -> 1 : cross product."""
    return jnp.cross(a, b)


def p_vv_t(a: jax.Array, b: jax.Array) -> jax.Array:
    """1 (x) 1 -> 2 : symmetric traceless outer product."""
    return traceless_sym(a[..., :, None] * b[..., None, :])


def p_tv_v(t: jax.Array, v: jax.Array) -> jax.Array:
    """2 (x) 1 -> 1 : matrix-vector action."""
    return jnp.einsum("...ij,...j->...i", t, v)


def p_tt_s(a: jax.Array, b: jax.Array) -> jax.Array:
    """2 (x) 2 -> 0 : Frobenius contraction."""
    return jnp.einsum("...ij,...ij->...", a, b)


def p_tt_t(a: jax.Array, b: jax.Array) -> jax.Array:
    """2 (x) 2 -> 2 : traceless symmetric part of the matrix product."""
    return traceless_sym(jnp.einsum("...ik,...kj->...ij", a, b))


# --- linear self-interactions (per-l channel mixing) ------------------------


def linear(x: Irreps, w_s, w_v, w_t) -> Irreps:
    """Channel-mixing linear map; acts per-l (equivariance-preserving)."""
    return Irreps(
        s=None if x.s is None else x.s @ w_s,
        v=None if x.v is None else jnp.einsum("ncd,ce->ned", x.v, w_v),
        t=None if x.t is None else jnp.einsum("ncij,ce->neij", x.t, w_t),
    )


def gate(x: Irreps, gates_v: jax.Array, gates_t: jax.Array) -> Irreps:
    """Gated nonlinearity: silu on scalars; vectors/tensors scaled by a
    sigmoid of dedicated scalar gates (Weiler-style, equivariant)."""
    return Irreps(
        s=None if x.s is None else jax.nn.silu(x.s),
        v=None if x.v is None else x.v * jax.nn.sigmoid(gates_v)[..., None],
        t=None if x.t is None else x.t * jax.nn.sigmoid(gates_t)[..., None, None],
    )


def rotate(x: Irreps, rot: jax.Array) -> Irreps:
    """Apply a rotation matrix to every feature (for equivariance tests)."""
    return Irreps(
        s=x.s,
        v=None if x.v is None else jnp.einsum("ij,ncj->nci", rot, x.v),
        t=None
        if x.t is None
        else jnp.einsum("ik,nckl,jl->ncij", rot, x.t, rot),
    )


def bessel_rbf(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Sinc-like radial Bessel basis with smooth polynomial cutoff envelope
    (NequIP eq. 6).  r: (m,) distances -> (m, n_rbf)."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[:, None] / cutoff) / r[:, None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    envelope = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5  # C2-smooth
    return basis * envelope[:, None]
