"""JAX version compatibility shims.

The codebase targets the modern surface (``jax.shard_map`` /
``jax.set_mesh``, jax >= 0.6); older jaxlib images (0.4.x, as baked into
some CI containers) only have ``jax.experimental.shard_map`` (with
``check_rep`` instead of ``check_vma``) and ``jax.sharding.use_mesh``.
Route through here instead of touching ``jax.*`` directly.
"""

from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with fallback to the 0.4.x experimental API."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    # 0.4.x check_rep has no replication rule for while/switch — always off
    check_rep = False if check_vma is None else check_vma
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep)


def set_mesh(mesh):
    """``jax.set_mesh`` context; falls back to ``jax.sharding.use_mesh``."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh  # 0.4.x: Mesh is itself the resource-env context manager
    return contextlib.nullcontext()
