"""repro — compression-optimized distributed BFS framework (JAX/TPU).

Reproduction (and beyond-paper extension) of Romera, "Optimizing Communication
by Compression for Multi-GPU Scalable Breadth-First Searches" (2017), rebuilt
as a TPU-native JAX framework with compressed collectives as a first-class
feature across BFS, LM training, GNN message passing and recsys serving.
"""

__version__ = "1.0.0"
