"""Static-shape compressed-stream codec + bucket ladder (single-device parts;
the collective paths are covered by tests/test_dist.py subprocesses)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import comm
from repro import comm as cc


@settings(max_examples=25, deadline=None)
@given(count=st.integers(0, 2048), seed=st.integers(0, 1 << 16))
def test_id_stream_roundtrip_property(count, seed):
    """PFOR-16 with static exception slots is exact for any sorted stream
    (large gaps land in the exception area)."""
    cap = 2048
    rng = np.random.default_rng(seed)
    # mixture of small gaps and occasional huge ones (> 2^16)
    gaps = rng.integers(0, 300, size=count)
    huge = rng.random(count) < 0.02
    gaps = np.where(huge, rng.integers(1 << 16, 1 << 24, size=count), gaps)
    ids = np.cumsum(gaps).astype(np.int32)
    padded = np.zeros(cap, np.int32)
    padded[:count] = ids
    spec = cc.IdStreamSpec(cap=cap, width=16)
    n_exc = int((gaps >> 16 > 0).sum())
    if n_exc > spec.exc_cap:
        return  # bucket selection would reject this stream
    words, meta = cc.pack_id_stream(jnp.asarray(padded), jnp.int32(count), spec)
    assert words.shape[0] == spec.n_words
    out, out_count = cc.unpack_id_stream(words, meta, spec, fill=-1)
    assert int(out_count) == count
    np.testing.assert_array_equal(np.asarray(out)[:count], ids)


def test_bitmap_roundtrip():
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.random(4096) < 0.3)
    words = cc.pack_bitmap(bits)
    assert words.shape[0] == 4096 // 32
    np.testing.assert_array_equal(np.asarray(cc.unpack_bitmap(words)), np.asarray(bits))


def test_bucket_ladder_sizes_and_selection():
    s = 1 << 16
    ladder = cc.BucketLadder.default(s)
    assert ladder.n_branches >= 2
    # word counts ascend; bitmap is the fallback floor
    sizes = [ladder.words_for_branch(i) for i in range(ladder.n_branches)]
    assert sizes[-1] == s // 32
    assert all(a < b for a, b in zip(sizes[:-1], sizes[1:])), sizes
    # sparse frontier -> smallest bucket; dense -> bitmap
    assert int(ladder.bucket_for(jnp.int32(10), jnp.int32(0))) == 0
    assert int(ladder.bucket_for(jnp.int32(s), jnp.int32(0))) == len(ladder.specs)
    # exception overflow forces escalation
    assert int(
        ladder.bucket_for(jnp.int32(10), jnp.int32(ladder.specs[0].exc_cap + 1))
    ) > 0


def test_compressed_words_beat_bitmap_beat_raw():
    """The three wire formats order as paper predicts: packed << bitmap << raw."""
    s = 1 << 16
    ladder = cc.BucketLadder.default(s)
    raw_words = s  # 32-bit id slots
    bitmap_words = s // 32
    sparse_words = ladder.specs[0].n_words
    assert sparse_words < bitmap_words < raw_words
    # data reduction vs raw exceeds the paper's 90% once sparse bucket hits
    assert 1 - sparse_words / raw_words > 0.90


# ---------------------------------------------------------------------------
# geometry-boundary round trips + engine bucket choice (repro.comm)
# ---------------------------------------------------------------------------


def test_id_stream_roundtrip_count_at_cap():
    """count == cap: every slot carries a real id, none spill."""
    cap = 1024
    spec = comm.IdStreamSpec(cap=cap)
    ids = (np.arange(cap, dtype=np.int64) * 3 + 1).astype(np.int32)  # no exceptions
    words, meta = comm.pack_id_stream(jnp.asarray(ids), jnp.int32(cap), spec)
    assert int(meta[0]) == cap and int(meta[1]) == 0
    out, count = comm.unpack_id_stream(words, meta, spec, fill=-1)
    assert int(count) == cap
    np.testing.assert_array_equal(np.asarray(out), ids)


def test_id_stream_roundtrip_exceptions_at_cap():
    """exc_count == exc_cap: the exception area is exactly full."""
    cap = 1024
    spec = comm.IdStreamSpec(cap=cap)
    count = 256
    gaps = np.ones(count, np.int64)
    gaps[:spec.exc_cap] = 1 << 17  # exactly exc_cap gaps overflow 16 bits
    ids = np.cumsum(gaps).astype(np.int32)
    padded = np.zeros(cap, np.int32)
    padded[:count] = ids
    words, meta = comm.pack_id_stream(jnp.asarray(padded), jnp.int32(count), spec)
    assert int(meta[1]) == spec.exc_cap
    out, out_count = comm.unpack_id_stream(words, meta, spec, fill=-1)
    assert int(out_count) == count
    np.testing.assert_array_equal(np.asarray(out)[:count], ids)


def test_id_stream_roundtrip_empty():
    """count == 0: meta is all-zero and unpack returns only fill."""
    cap = 1024
    spec = comm.IdStreamSpec(cap=cap)
    words, meta = comm.pack_id_stream(jnp.zeros(cap, jnp.int32), jnp.int32(0), spec)
    assert int(meta[0]) == 0 and int(meta[1]) == 0
    out, count = comm.unpack_id_stream(words, meta, spec, fill=7)
    assert int(count) == 0
    assert np.all(np.asarray(out) == 7)


def test_ladder_stores_payload_width():
    """payload_width lives on the ladder: words_for_branch needs no re-pass
    and the per-bucket formats bake it in."""
    ladder = comm.BucketLadder.default(1 << 16, floor_words=1 << 16, payload_width=16)
    assert ladder.payload_width == 16
    assert len(ladder.specs) >= 2
    for i, f in enumerate(ladder.formats()):
        assert f.payload_width == 16
        assert ladder.words_for_branch(i) == f.data_words
    # the payload makes every bucket strictly wider than the payload-free one
    bare = comm.BucketLadder.default(1 << 16, floor_words=1 << 16)
    for i in range(min(len(ladder.specs), len(bare.specs))):
        assert ladder.words_for_branch(i) > bare.words_for_branch(i)


def test_bucket_choice_monotone_in_count_and_exceptions():
    """Ladder bucket choice is monotone: more ids (or more exceptions)
    never selects a smaller capacity class."""
    s = 1 << 16
    ladder = comm.BucketLadder.default(s, floor_words=s)
    assert len(ladder.specs) >= 2
    prev = 0
    for count in range(0, s + 1, 4096):
        b = int(ladder.bucket_for(jnp.int32(count), jnp.int32(0)))
        assert b >= prev, (count, b, prev)
        prev = b
    assert prev == len(ladder.specs)  # full count lands on the dense fallback
    prev = 0
    for exc in range(0, ladder.specs[-1].exc_cap + 2, 64):
        b = int(ladder.bucket_for(jnp.int32(10), jnp.int32(exc)))
        assert b >= prev, (exc, b, prev)
        prev = b


def test_comm_stats_moved_bytes_accounting():
    """moved_bytes records true wire traffic next to the HLO-parity nbytes:
    identity permute pairs move nothing, gathers keep their own chunk, and
    the ring all-reduce moves 2(g-1)/g of its operand."""
    stats = comm.CommStats()
    # default: moved == nbytes (host-replay adds are already true traffic)
    stats.add("zone", "fmt", "all-to-all", 100)
    stats.add("zone", "fmt", "all-to-all", 50)
    (rec,) = stats.records()
    assert rec.nbytes == 150 and rec.moved_bytes == 150
    # trace-style record with an explicit moved count
    stats.record("t", "membership", "collective-permute", "words", 8192,
                 moved_bytes=5461)
    rec = [r for r in stats.records() if r.phase == "t"][0]
    assert rec.nbytes == 8192 and rec.moved_bytes == 5461
    assert rec.hlo_bytes == 8192  # HLO parity never uses moved bytes
    assert stats.per_phase_moved()["t"] == 5461
    assert stats.total_moved_bytes == 150 + 5461
    # re-recording with a different moved count is rejected like nbytes
    with pytest.raises(ValueError):
        stats.record("t", "membership", "collective-permute", "words", 8192,
                     moved_bytes=0)


def test_engine_ppermute_identity_pairs_move_nothing():
    """An all-self-pairs transpose records full HLO operand bytes but zero
    moved bytes (the Partition2D transpose always contains self-sends)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat

    mesh = jax.make_mesh((1,), ("x",))
    stats = comm.CommStats()

    def body(x):
        ex = comm.AdaptiveExchange("bfs/transpose", "x", 1, None, stats)
        return ex.ppermute(x.reshape(-1), [(0, 0)], fmt="membership")

    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P()))
    np.testing.assert_array_equal(
        np.asarray(f(jnp.arange(64, dtype=jnp.int32))), np.arange(64)
    )
    (rec,) = stats.records()
    assert rec.collective == "collective-permute"
    assert rec.nbytes == 64 * 4 and rec.moved_bytes == 0


def test_butterfly_stage_collectives_single_rank():
    """ppermute_min_block / ppermute_membership_block round-trip on a
    single-rank axis with an identity pair (the degenerate stage): packed
    streams reconstruct the dense candidates / membership exactly."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import butterfly
    from repro.comm import collectives as cc_new

    s, n = 8192, 1 << 15
    ladder, floor = butterfly.row_wire(s, n)
    assert ladder.specs, "row wire must keep sparse buckets at this geometry"
    mesh = jax.make_mesh((1,), ("x",))
    rng = np.random.default_rng(0)
    for planes in (1, 3):  # single-source wire and a multi-source plane block
        for density in (0.001, 0.02, 0.9):
            block_np = np.where(
                rng.random((2, planes, s)) < density,
                rng.integers(0, n, size=(2, planes, s)),
                np.iinfo(np.int32).max,
            ).astype(np.int32)

            def body(block, _p=planes):
                ex = comm.AdaptiveExchange("stage", "x", 1, ladder, None,
                                           planes=_p)
                return cc_new.ppermute_min_block(
                    ex, block.reshape(2, _p, s), [(0, 0)], ladder, floor,
                    gate=jnp.bool_(True),
                )

            f = jax.jit(
                compat.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P())
            )
            out = np.asarray(f(jnp.asarray(block_np)))
            np.testing.assert_array_equal(
                out, block_np, err_msg=f"b={planes} d={density}"
            )

            bits_np = rng.random((2, planes, s)) < density
            col_ladder, _ = butterfly.unreached_wire(s)

            def body_m(bits, _p=planes):
                ex = comm.AdaptiveExchange("stage", "x", 1, col_ladder, None,
                                           planes=_p)
                return cc_new.ppermute_membership_block(
                    ex, bits.reshape(2, _p, s), [(0, 0)], col_ladder,
                    gate=jnp.bool_(True),
                )

            fm = jax.jit(
                compat.shard_map(body_m, mesh=mesh, in_specs=P(), out_specs=P())
            )
            np.testing.assert_array_equal(
                np.asarray(fm(jnp.asarray(bits_np))), bits_np,
                err_msg=f"b={planes} d={density}",
            )


@pytest.mark.slow
def test_adaptive_exchange_bucket_choice_monotone():
    """End-to-end through AdaptiveExchange.dispatch: denser memberships
    dispatch to monotonically larger branches, and the consensus pmax is
    byte-accounted."""
    import functools

    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat

    s = 1 << 16
    ladder = comm.BucketLadder.default(s, floor_words=s)
    mesh = jax.make_mesh((1,), ("x",))
    stats = comm.CommStats()

    def which_branch(bits):
        ex = comm.AdaptiveExchange("test", "x", 1, ladder, stats)
        _, count, exc = comm.stream_stats(bits, s)
        branches = [
            functools.partial(lambda i, _: jnp.int32(i), i)
            for i in range(ladder.n_branches)
        ]
        return ex.dispatch(ladder.bucket_for(count, exc), branches)

    f = jax.jit(compat.shard_map(which_branch, mesh=mesh, in_specs=P(), out_specs=P()))
    rng = np.random.default_rng(0)
    prev = 0
    for density in (0.002, 0.02, 0.1, 0.6):
        b = int(f(jnp.asarray(rng.random(s) < density)))
        assert b >= prev, (density, b, prev)
        prev = b
    assert prev == len(ladder.specs)  # densest input -> dense fallback
    assert any(r.fmt == "consensus" for r in stats.records())
