"""Static-shape compressed-stream codec + bucket ladder (single-device parts;
the collective paths are covered by tests/test_dist.py subprocesses)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.compression import collectives as cc


@settings(max_examples=25, deadline=None)
@given(count=st.integers(0, 2048), seed=st.integers(0, 1 << 16))
def test_id_stream_roundtrip_property(count, seed):
    """PFOR-16 with static exception slots is exact for any sorted stream
    (large gaps land in the exception area)."""
    cap = 2048
    rng = np.random.default_rng(seed)
    # mixture of small gaps and occasional huge ones (> 2^16)
    gaps = rng.integers(0, 300, size=count)
    huge = rng.random(count) < 0.02
    gaps = np.where(huge, rng.integers(1 << 16, 1 << 24, size=count), gaps)
    ids = np.cumsum(gaps).astype(np.int32)
    padded = np.zeros(cap, np.int32)
    padded[:count] = ids
    spec = cc.IdStreamSpec(cap=cap, width=16)
    n_exc = int((gaps >> 16 > 0).sum())
    if n_exc > spec.exc_cap:
        return  # bucket selection would reject this stream
    words, meta = cc.pack_id_stream(jnp.asarray(padded), jnp.int32(count), spec)
    assert words.shape[0] == spec.n_words
    out, out_count = cc.unpack_id_stream(words, meta, spec, fill=-1)
    assert int(out_count) == count
    np.testing.assert_array_equal(np.asarray(out)[:count], ids)


def test_bitmap_roundtrip():
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.random(4096) < 0.3)
    words = cc.pack_bitmap(bits)
    assert words.shape[0] == 4096 // 32
    np.testing.assert_array_equal(np.asarray(cc.unpack_bitmap(words)), np.asarray(bits))


def test_bucket_ladder_sizes_and_selection():
    s = 1 << 16
    ladder = cc.BucketLadder.default(s)
    assert ladder.n_branches >= 2
    # word counts ascend; bitmap is the fallback floor
    sizes = [ladder.words_for_branch(i) for i in range(ladder.n_branches)]
    assert sizes[-1] == s // 32
    assert all(a < b for a, b in zip(sizes[:-1], sizes[1:])), sizes
    # sparse frontier -> smallest bucket; dense -> bitmap
    assert int(ladder.bucket_for(jnp.int32(10), jnp.int32(0))) == 0
    assert int(ladder.bucket_for(jnp.int32(s), jnp.int32(0))) == len(ladder.specs)
    # exception overflow forces escalation
    assert int(
        ladder.bucket_for(jnp.int32(10), jnp.int32(ladder.specs[0].exc_cap + 1))
    ) > 0


def test_compressed_words_beat_bitmap_beat_raw():
    """The three wire formats order as paper predicts: packed << bitmap << raw."""
    s = 1 << 16
    ladder = cc.BucketLadder.default(s)
    raw_words = s  # 32-bit id slots
    bitmap_words = s // 32
    sparse_words = ladder.specs[0].n_words
    assert sparse_words < bitmap_words < raw_words
    # data reduction vs raw exceeds the paper's 90% once sparse bucket hits
    assert 1 - sparse_words / raw_words > 0.90
