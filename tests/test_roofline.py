"""Roofline extraction: HLO collective parsing + term arithmetic."""

import os
import subprocess
import sys

import pytest

from repro.launch import roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FAKE_HLO = """\
HloModule test

%while_body.7 (p: (f32[128,256])) -> (f32[128,256]) {
  %arg = f32[128,256] parameter(0)
  %ag = f32[512,256] all-gather(%arg), dimensions={0}
  %ar = f32[128,256] all-reduce(%arg), to_apply=%add
  ROOT %t = (f32[128,256]) tuple(%ar)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %w = (f32[128,256]) while((f32[128,256]) %tup), condition=%cond.1, body=%while_body.7
  %cp = bf16[64,64] collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %out = f32[128,256] get-tuple-element(%w), index=0
}
"""


def test_shape_bytes():
    assert roofline._shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert roofline._shape_bytes("bf16[8]") == 16
    assert roofline._shape_bytes("(f32[2,2], s8[4])") == 20
    assert roofline._shape_bytes("pred[]") == 1  # scalar = one element


def test_parse_collectives_loop_scaling():
    stats1 = roofline.parse_collectives(_FAKE_HLO, loop_mult=1.0)
    ag = 512 * 256 * 4
    ar = 128 * 256 * 4 * 2  # all-reduce counts 2x
    cp = 64 * 64 * 2
    assert stats1.per_op["all-gather"] == ag
    assert stats1.per_op["all-reduce"] == ar
    assert stats1.per_op["collective-permute"] == cp
    # ops inside the while body scale by the trip count; top-level ops don't
    stats10 = roofline.parse_collectives(_FAKE_HLO, loop_mult=10.0)
    assert stats10.per_op["all-gather"] == 10 * ag
    assert stats10.per_op["all-reduce"] == 10 * ar
    assert stats10.per_op["collective-permute"] == cp


def test_roofline_terms_arithmetic():
    t = roofline.RooflineTerms(
        compute_s=1.0, memory_s=2.0, collective_s=0.5,
        hlo_flops=1e12, hlo_bytes=1e12, collective_bytes=1e10,
        model_flops=roofline.PEAK_FLOPS * 256,  # 1s of ideal all-chip compute
        chips=256,
    )
    assert t.dominant == "memory"
    assert t.bound_s == 2.0
    assert abs(t.roofline_fraction - 0.5) < 1e-9  # 1s ideal / 2s bound


@pytest.mark.slow
def test_parse_real_compiled_program():
    """Collectives of a real SPMD-compiled psum program are found."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    snippet = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch import roofline
mesh = jax.make_mesh((4,), ("data",))
def f(x):
    return jax.lax.psum(x * 2, "data")
from repro import compat
m = compat.shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)
compiled = jax.jit(m).lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
stats = roofline.parse_collectives(compiled.as_text())
assert stats.n_ops >= 1, compiled.as_text()[:500]
assert stats.per_op.get("all-reduce", 0) > 0
print("REAL HLO PARSE OK", stats.per_op)
"""
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REAL HLO PARSE OK" in out.stdout


@pytest.mark.slow
def test_comm_stats_match_hlo_all_modes():
    """Acceptance: the CommStats ledger every collective reports through
    agrees per op kind with the collective operand bytes parsed out of the
    compiled HLO, for the raw / bitmap / auto wire plans (the auto row
    ladder has sparse buckets at s=16384, so the lax.switch branches are
    in the HLO and in the ledger)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    snippet = """
import jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.launch import roofline
part = csrmod.Partition2D(n=1 << 16, n_orig=1 << 16, rows=2, cols=2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
blk = jax.ShapeDtypeStruct((2, 2, 4096), jnp.int32)
for mode in ("raw", "bitmap", "auto"):
    stats = CommStats()
    fn = dbfs.build_bfs(mesh, part, dbfs.DistBFSConfig(mode=mode), stats=stats)
    compiled = jax.jit(fn).lower(blk, blk, jax.ShapeDtypeStruct((), jnp.int32)).compile()
    cmp = roofline.compare_comm_stats(stats, compiled.as_text())
    assert cmp.match, (mode, cmp.diff())
    # every BFS exchange zone is in the ledger
    assert set(cmp.per_phase) == {"bfs/column", "bfs/row", "bfs/transpose", "bfs/termination"}, cmp.per_phase
print("COMM STATS MATCH OK")
"""
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COMM STATS MATCH OK" in out.stdout
