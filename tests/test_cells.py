"""Cell-builder integration: every (arch x shape) cell lowers coherently.

Full compiles for the production meshes happen in launch/dryrun.py (and its
artifacts are checked into experiments/); here every cell is *lowered* on a
small forced-device mesh in a subprocess — catching shape/sharding drift in
CI without the 512-device compile cost — plus one full dryrun.run_cell
execution end to end.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int, timeout: int = 1800) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet], env=env, capture_output=True, text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout[-2000:]}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_all_cells_lower_on_small_mesh():
    out = _run(
        """
import jax
from jax.sharding import Mesh
from repro.launch import cells
mesh = jax.make_mesh((2, 2), ("data", "model"))
n_ok = n_skip = 0
for arch, shape in cells.all_cells():
    cell = cells.build_cell(arch, shape, mesh)
    if cell.kind == "skip":
        n_skip += 1
        continue
    jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(*cell.args)
    assert cell.meta["model_flops"] > 0, (arch, shape)
    n_ok += 1
print(f"LOWERED {n_ok} cells, {n_skip} skips")
assert n_skip == 5  # long_500k x 5 LM archs
assert n_ok + n_skip == len(cells.all_cells())
""",
        devices=4,
    )
    assert "LOWERED 38 cells, 5 skips" in out  # 10 archs x 4 + graph500 x 3 - 5


@pytest.mark.slow
def test_perf_variants_lower():
    """The §Perf variant knobs still produce lowerable cells."""
    out = _run(
        """
import jax
from repro import compat
from repro.launch import cells
mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch, shape, variant in [
    ("deepseek-v2-236b", "train_4k", "bf16-fullremat-moepin-experttp"),
    ("gemma-2b", "decode_32k", "tpserve"),
    ("autoint", "serve_bulk", "modeltable-int8table"),
    ("graph500", "scale30", "ecap15-bitmaponly"),
]:
    cell = cells.build_cell(arch, shape, mesh, variant=variant)
    with compat.set_mesh(mesh):  # bare-P sharding constraints need a mesh
        jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(*cell.args)
print("VARIANTS OK")
""",
        devices=4,
    )
    assert "VARIANTS OK" in out


@pytest.mark.slow
def test_dryrun_driver_end_to_end(tmp_path):
    """dryrun.run_cell on the real 512-device mesh, one light cell."""
    out = _run(
        f"""
import repro.launch.dryrun as d
rec = d.run_cell("gemma-2b", "prefill_32k", multi_pod=True, out_dir=r"{tmp_path}")
assert rec["status"] == "ok", rec.get("error")
assert rec["roofline"]["collective_bytes"] > 0
assert rec["memory"]["temp_bytes"] > 0
rec2 = d.run_cell("minicpm-2b", "long_500k", multi_pod=False, out_dir=r"{tmp_path}")
assert rec2["status"] == "skip" and "sub-quadratic" in rec2["skip_reason"]
print("DRYRUN DRIVER OK", rec["roofline"]["dominant"])
""",
        devices=1,  # dryrun module forces 512 itself before importing jax
    )
    assert "DRYRUN DRIVER OK" in out
