"""Serving engine: slot batching, greedy decode correctness, drain."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.serve import engine as eng


def _cfg():
    return tfm.TransformerConfig(
        name="serve-test", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16,
        compute_dtype=jnp.float32,
    )


def test_engine_drains_more_requests_than_slots():
    cfg = _cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    e = eng.Engine(cfg, params, batch_slots=3, max_seq=48)
    reqs = [eng.Request(rid=i, prompt=np.arange(2 + i) % 64, max_new=4) for i in range(7)]
    for r in reqs:
        e.submit(r)
    e.run_until_drained()
    assert all(r.done and len(r.out) == 4 for r in reqs)


def test_engine_greedy_matches_teacher_forced():
    """The first generated token equals argmax of the forward pass over the
    prompt (greedy decode == teacher-forced continuation)."""
    cfg = _cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray([5, 9, 13, 21], np.int32)
    e = eng.Engine(cfg, params, batch_slots=2, max_seq=32)
    req = eng.Request(rid=0, prompt=prompt, max_new=3)
    e.submit(req)
    e.run_until_drained()
    logits, _ = tfm.forward(cfg, params, jnp.asarray(prompt)[None])
    expect = int(jnp.argmax(logits[0, -1]))
    assert req.out[0] == expect


def test_engine_isolation_between_slots():
    """A request's output is independent of what shares the batch."""
    cfg = _cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.asarray([1, 2, 3], np.int32)

    e1 = eng.Engine(cfg, params, batch_slots=1, max_seq=32)
    r_solo = eng.Request(rid=0, prompt=prompt, max_new=4)
    e1.submit(r_solo)
    e1.run_until_drained()

    e2 = eng.Engine(cfg, params, batch_slots=4, max_seq=32)
    rs = [eng.Request(rid=i, prompt=np.arange(1 + i) % 64, max_new=4) for i in range(3)]
    r_batched = eng.Request(rid=9, prompt=prompt, max_new=4)
    for r in rs + [r_batched]:
        e2.submit(r)
    e2.run_until_drained()
    assert r_batched.out == r_solo.out
