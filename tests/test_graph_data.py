"""Graph data pipelines: neighbor sampler invariants, shape-spec exactness."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import graphs as dgraphs
from repro.graphgen import builder, kronecker


def _graph(scale=10, seed=1):
    return builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)


def test_sampled_shape_matches_minibatch_spec():
    """The minibatch_lg cell's static shapes come from the fanout spec."""
    n, m = dgraphs.sampled_shape(1024, (15, 10))
    assert n == 1024 * (1 + 15 + 150) == 169_984
    assert m == 1024 * 15 + 1024 * 15 * 10 == 168_960


def test_neighbor_sampler_block_structure():
    g = _graph()
    sampler = dgraphs.NeighborSampler(g, fanouts=(5, 3), seed=0)
    seeds = np.arange(64)
    nodes, src, dst = sampler.sample(seeds)
    n_expect, m_expect = dgraphs.sampled_shape(64, (5, 3))
    assert nodes.shape == (n_expect,)
    assert src.shape == dst.shape == (m_expect,)
    # layer 0 is exactly the seeds
    np.testing.assert_array_equal(nodes[:64], seeds)
    # message edges point from deeper layer to shallower (src idx > dst idx)
    assert (src > dst).all()
    assert src.max() < n_expect and dst.max() < 64 + 64 * 5


def test_neighbor_sampler_edges_are_real_or_selfloops():
    """Every sampled neighbor is a true graph neighbor (or a self-loop for
    isolated vertices) — the sampler is real, not a stub."""
    g = _graph()
    sampler = dgraphs.NeighborSampler(g, fanouts=(4,), seed=1)
    seeds = np.arange(128)
    nodes, src, dst = sampler.sample(seeds)
    for e in range(src.size):
        parent = nodes[dst[e]]
        child = nodes[src[e]]
        nbrs = g.neighbors(parent)
        assert child in nbrs or (child == parent and nbrs.size == 0), (parent, child)


def test_neighbor_sampler_batch_mask_and_targets():
    g = _graph()
    sampler = dgraphs.NeighborSampler(g, fanouts=(3, 2), seed=2)
    gb = sampler.batch(np.arange(32), d_feat=8)
    n_expect, _ = dgraphs.sampled_shape(32, (3, 2))
    assert gb.nf.shape == (n_expect, 8)
    assert gb.mask.sum() == 32  # loss only on seeds
    assert gb.mask[:32].all() and not gb.mask[32:].any()


@settings(max_examples=10, deadline=None)
@given(n_nodes=st.integers(50, 3000), m_mult=st.integers(1, 8), seed=st.integers(0, 999))
def test_synthetic_graph_exact_shape_property(n_nodes, m_mult, seed):
    """Shape-spec generators hit the requested (n, m) EXACTLY — the 40-cell
    grid depends on it."""
    n_edges = n_nodes * m_mult
    gb = dgraphs.synthetic_graph(n_nodes, n_edges, d_feat=4, seed=seed)
    assert gb.nf.shape == (n_nodes, 4)
    assert gb.src.shape == gb.dst.shape == (n_edges,)
    assert gb.src.max() < n_nodes and gb.dst.max() < n_nodes
    assert gb.src.min() >= 0


def test_sampled_minibatch_trains_end_to_end():
    """The minibatch pipeline: sampler block -> GNN loss/grad (the real
    GraphSAGE-style path behind the minibatch_lg cells)."""
    import jax
    import jax.numpy as jnp

    from repro.models import gnn

    g = _graph()
    sampler = dgraphs.NeighborSampler(g, fanouts=(4, 3), seed=3)
    gb = sampler.batch(np.arange(16), d_feat=8)
    cfg = gnn.GraphCastConfig(n_layers=2, d_hidden=16, d_in=8, d_out=16)
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    graph = gnn.Graph(
        nf=jnp.asarray(gb.nf), src=jnp.asarray(gb.src), dst=jnp.asarray(gb.dst),
        pos=jnp.asarray(gb.pos),
    )
    batch = {"graph": graph, "targets": jnp.asarray(gb.targets),
             "mask": jnp.asarray(gb.mask)}
    loss, grads = jax.value_and_grad(lambda p: gnn.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(grads))


def test_molecule_batch_block_diagonal():
    gb = dgraphs.molecule_batch(n_mols=16, nodes_per=30, edges_per=64, d_feat=16, seed=0)
    assert gb.nf.shape == (480, 16)
    assert gb.src.shape == (1024,)
    # edges never cross molecule boundaries
    assert np.array_equal(gb.src // 30, gb.dst // 30)
