"""Optimizer + gradient-compression correctness."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import adamw, grad_compress


def test_adamw_matches_reference_numpy():
    """One step against a hand-rolled numpy AdamW (bias-corrected)."""
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                            grad_clip=1e9, warmup_steps=0, total_steps=10**6)
    p = {"w": jnp.asarray(np.array([1.0, -2.0, 3.0], np.float32))}
    g = {"w": jnp.asarray(np.array([0.5, 0.25, -1.0], np.float32))}
    st_ = adamw.init(p)
    p1, st1 = adamw.apply(cfg, p, g, st_)
    # numpy reference
    gw = np.array([0.5, 0.25, -1.0])
    m = 0.1 * gw
    v = 0.01 * gw * gw
    mhat, vhat = m / (1 - 0.9), v / (1 - 0.99)
    upd = mhat / (np.sqrt(vhat) + 1e-8)
    ref = np.array([1.0, -2.0, 3.0]) - 0.1 * (upd + 0.01 * np.array([1.0, -2.0, 3.0]))
    np.testing.assert_allclose(np.asarray(p1["w"]), ref, rtol=1e-5)


def test_grad_clip():
    cfg = adamw.AdamWConfig(lr=0.0, grad_clip=1.0, warmup_steps=0, total_steps=100)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, st1 = adamw.apply(cfg, p, g, adamw.init(p))
    # m = (1-b1) * clipped grad; clipped norm == 1
    m_norm = float(jnp.linalg.norm(st1.m["w"])) / (1 - cfg.b1)
    np.testing.assert_allclose(m_norm, 1.0, rtol=1e-5)


def test_wsd_schedule_phases():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=100, total_steps=1000, decay_frac=0.1,
                            min_lr_frac=0.1)
    lr = lambda s: float(adamw.wsd_schedule(cfg, jnp.int32(s)))  # noqa: E731
    assert lr(0) == 0.0
    assert abs(lr(50) - 0.5) < 1e-6  # warmup is linear
    assert abs(lr(500) - 1.0) < 1e-6  # stable plateau
    assert abs(lr(899) - 1.0) < 1e-2  # plateau holds until 90%
    assert lr(950) < 0.6  # sharp decay
    assert abs(lr(1000) - 0.1) < 1e-6  # floor


def test_error_feedback_converges_on_quadratic():
    """EF-compressed gradients reach the optimum a plain run reaches —
    accumulated quantization error stays bounded (Karimireddy)."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=256).astype(np.float32))

    def run(compressed: bool) -> float:
        w = jnp.zeros(256)
        ef = grad_compress.init({"w": w})
        cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                                total_steps=10**6)
        st_ = adamw.init({"w": w})
        p = {"w": w}
        for _ in range(300):
            g = {"w": 2 * (p["w"] - target)}
            if compressed:
                g, ef = grad_compress.ef_step(g, ef)
            p, st_ = adamw.apply(cfg, p, g, st_)
        return float(jnp.mean((p["w"] - target) ** 2))

    assert run(True) < 1e-3
    assert run(True) < 10 * max(run(False), 1e-6) + 1e-3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1 << 16))
def test_compress_decompress_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=777).astype(np.float32) * 5)
    out = grad_compress.compress_decompress(g)
    # per-128-group max-abs scaling bounds the error at scale/2
    assert float(jnp.abs(out - g).max()) <= float(jnp.abs(g).max()) / 254 + 1e-6
