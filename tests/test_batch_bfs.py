"""Multi-source batch BFS: the B-plane axis across both drivers.

The contract: a batched run over ``roots (B,)`` produces, per plane,
parent/level arrays *identical* to B independent single-source runs — for
every traversal policy, every wire plan, and both drivers — while every
distributed exchange carries all B planes under ONE wire header and ONE
bucket consensus.  The ledger shows the split: payload collectives are
attributed per plane under ``{phase}@p{k}`` sub-zones that still reconcile
1:1 with the lowered HLO in aggregate, while the shared rounds (bucket
pmax, degree psum) stay whole under their base phase.
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import formats
from repro.comm.ladder import BucketLadder
from repro.core import bfs, traversal
from repro.graphgen import builder

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _device_graph(g):
    return jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


def test_validate_roots_errors():
    """Satellite: bad roots fail fast with a clear message instead of the
    silent wraparound indexing of the ``parent.at[root]`` scatter."""
    n = 64
    g = builder.build_csr(np.array([[0, 1], [1, 2]]), n=n)
    src, dst = _device_graph(g)
    with pytest.raises(TypeError, match="integer"):
        bfs.bfs(src, dst, jnp.float32(0), n)
    with pytest.raises(ValueError, match="out of range"):
        bfs.bfs(src, dst, jnp.int32(n), n)
    with pytest.raises(ValueError, match="out of range"):
        bfs.bfs(src, dst, np.array([1, -3]), n)
    with pytest.raises(ValueError, match="duplicate"):
        bfs.bfs(src, dst, np.array([5, 0, 5]), n)
    with pytest.raises(ValueError, match="scalar or"):
        bfs.bfs(src, dst, np.zeros((2, 2), np.int32), n)
    with pytest.raises(ValueError, match="at least one"):
        bfs.bfs(src, dst, np.zeros((0,), np.int32), n)
    # well-formed roots pass through as int32, values untouched
    assert bfs.validate_roots(np.int64(3), n).dtype == jnp.int32
    np.testing.assert_array_equal(bfs.validate_roots([3, 0, 63], n), [3, 0, 63])


def test_plane_meta_roundtrip_and_header_amortization():
    """B id streams share one packed meta word per plane: the sideband
    halves per source, and the plane wire strictly undercuts B separate
    single-plane wires; dense formats scale linearly (no header to share)."""
    counts = jnp.array([0, 5, 1 << 16], jnp.int32)  # counts reach cap == 2**16
    excs = jnp.array([0, 3, 1 << 13], jnp.int32)  # exceptions reach cap / 8
    c2, e2 = formats.unpack_plane_meta(formats.pack_plane_meta(counts, excs))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(excs))

    ladder = BucketLadder.default(8192, floor_words=8192, payload_width=16)
    stream = next(
        f for f in ladder.formats() if isinstance(f, formats.IdStreamFormat)
    )
    for b in (2, 4, 8):
        batched = formats.plane_wire_bytes(stream, b)
        assert batched == 4 * (b * stream.data_words + formats.plane_meta_words(b))
        assert batched < b * stream.wire_bytes
        assert batched / b < stream.wire_bytes  # strictly cheaper per source
    dense = formats.DenseFormat(8192)
    assert formats.plane_wire_bytes(dense, 4) == 4 * dense.wire_bytes


def test_oracle_anticipatory_mf_signal():
    """The Beamer m_f edge signal flips the direction one level before the
    vertex count crosses alpha*n: a hub entering the frontier blows up the
    frontier edge count while the popcount still reads sparse."""
    oracle = traversal.DensityOracle(1000, alpha=0.25, beta=0.05, alpha_mf=14.0)
    # popcount alone: 100 < alpha*n = 250 -> stay top-down
    assert not bool(oracle.next_direction(np.int32(100), False))
    # same popcount, but the frontier touches half the remaining edges
    assert bool(
        oracle.next_direction(np.int32(100), False, m_f=np.int32(500), m_u=np.int32(1000))
    )
    assert not bool(
        oracle.next_direction(np.int32(100), False, m_f=np.int32(10), m_u=np.int32(100000))
    )
    # elementwise over source planes: one plane enters on m_f, one on the
    # popcount, one stays put
    out = oracle.next_direction(
        np.array([100, 300, 100]),
        np.array([False, False, False]),
        m_f=np.array([500, 0, 0]),
        m_u=np.array([1000, 10**6, 10**6]),
    )
    np.testing.assert_array_equal(np.asarray(out), [True, True, False])
    # Beamer's C_TB growth guard: a shrinking tail frontier whose m_f
    # exceeds a collapsed m_u must NOT flap into the pull wire; the
    # popcount rule is unaffected by the guard
    out = oracle.next_direction(
        np.array([100, 300]),
        np.array([False, False]),
        m_f=np.array([500, 0]),
        m_u=np.array([1000, 10**6]),
        growing=np.array([False, False]),
    )
    np.testing.assert_array_equal(np.asarray(out), [False, True])
    assert bool(
        oracle.next_direction(np.int32(100), False, m_f=np.int32(500),
                              m_u=np.int32(1000), growing=np.bool_(True))
    )


def test_plane_counts_matches_per_plane_sums():
    rng = np.random.default_rng(0)
    for n in (3000, 4096):  # unaligned and aligned to the 1024-bit chunk
        oracle = traversal.DensityOracle(n)
        bits = rng.random((3, n)) < np.array([[0.0], [0.01], [0.6]])
        np.testing.assert_array_equal(
            np.asarray(oracle.plane_counts(jnp.asarray(bits))), bits.sum(axis=1)
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1 << 16))
def test_batched_equals_singles_single_device(seed):
    """Property: bfs() with (B,) roots == B single-source runs, per plane,
    for every traversal policy; n_levels is the deepest plane's depth."""
    rng = np.random.default_rng(seed)
    n = 256
    m = int(rng.integers(1, 2048))
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    src, dst = _device_graph(g)
    roots = rng.choice(n, size=3, replace=False).astype(np.int32)
    for policy in traversal.POLICIES:
        res_b = bfs.bfs(src, dst, jnp.asarray(roots), g.n, policy=policy)
        assert res_b.parent.shape == (3, g.n)
        depths = []
        for k, r in enumerate(roots):
            res_1 = bfs.bfs(src, dst, jnp.int32(int(r)), g.n, policy=policy)
            np.testing.assert_array_equal(
                np.asarray(res_b.parent)[k], np.asarray(res_1.parent),
                err_msg=f"{policy} root {r}",
            )
            np.testing.assert_array_equal(
                np.asarray(res_b.level)[k], np.asarray(res_1.level),
                err_msg=f"{policy} root {r}",
            )
            depths.append(int(res_1.n_levels))
        assert int(res_b.n_levels) == max(depths), (policy, depths)


def _run(snippet: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


_BATCH_EQUIV_SNIPPET = """
import os, sys
try:
    import hypothesis
except ImportError:
    sys.path.insert(0, os.path.join(r"%(repo)s", "tests", "_shims"))
from hypothesis import given, settings
from hypothesis import strategies as st
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder
n = 1 << 10
ROWS, COLS, B = 2, %(cols)d, 3
mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
g0 = builder.build_csr(np.array([[0, 1]]), n=n)
part = csrmod.partition_2d(g0, rows=ROWS, cols=COLS, e_cap_multiple=1024).part
fns = {}
for mode in ("raw", "bitmap", "auto", "btfly"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, alpha=0.01, beta=0.002)
        fns[mode, pol] = (dbfs.build_bfs(mesh, part, cfg), cfg)

@settings(max_examples=%(examples)d, deadline=None)
@given(seed=st.integers(0, 1 << 16))
def prop(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 400))
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS, e_cap_multiple=1024)
    assert bg.e_cap == 1024  # pinned -> the compiled fns are reused
    roots = rng.choice(n, size=B, replace=False).astype(np.int32)
    for (mode, pol), (fn, cfg) in fns.items():
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        pb, lb, db = fn(src_l, dst_l, jnp.asarray(roots))
        pb, lb = np.asarray(pb), np.asarray(lb)
        assert pb.shape[0] == B
        for k, r in enumerate(roots):
            p1, l1, d1 = fn(src_l, dst_l, jnp.int32(int(r)))
            np.testing.assert_array_equal(
                pb[k], np.asarray(p1), err_msg=f"{mode}/{pol}/root {r}")
            np.testing.assert_array_equal(
                lb[k], np.asarray(l1), err_msg=f"{mode}/{pol}/root {r}")

prop()
# root validation rides the same wrapper in the distributed driver
fn, cfg = fns["raw", "top_down"]
src_l, dst_l = dbfs.shard_blocked(
    mesh, csrmod.partition_2d(g0, rows=ROWS, cols=COLS, e_cap_multiple=1024), cfg)
for bad in (np.array([1, 1], np.int32), np.array([n], np.int32)):
    try:
        fn(src_l, dst_l, bad)
        raise SystemExit(f"no error for roots {bad}")
    except ValueError:
        pass
print("BATCH EQUIV OK")
"""


@pytest.mark.slow
def test_batched_equals_singles_all_plans_4dev():
    """Satellite acceptance: batched distributed BFS equals B independent
    single-source runs for all 4 wire plans x 3 policies on the C=2 grid
    (hypothesis drives the graphs; low alpha forces direction_opt through
    its bottom-up branch so both wires carry real planes)."""
    out = _run(
        _BATCH_EQUIV_SNIPPET % {"repo": REPO, "cols": 2, "examples": 5},
        devices=4,
    )
    assert "BATCH EQUIV OK" in out


@pytest.mark.slow
def test_batched_equals_singles_c3_6dev():
    """Same property on the C=3 grid: the batched planes ride the butterfly
    fold/unfold stages and the non-power-of-two alltoall geometry."""
    out = _run(
        _BATCH_EQUIV_SNIPPET % {"repo": REPO, "cols": 3, "examples": 3},
        devices=6,
    )
    assert "BATCH EQUIV OK" in out


@pytest.mark.slow
def test_per_plane_comm_stats_match_hlo_4dev():
    """Tentpole acceptance: at B=3 the CommStats ledger reconciles 1:1 with
    the lowered HLO for all 4 plans x 3 policies, every plane-carrying zone
    splits into exactly B ``@p{k}`` sub-zones, and the shared rounds — the
    bucket pmax consensus and the degree psum — are never split (ONE round
    serves all planes: the amortization the ledger must show)."""
    out = _run(
        """
import jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.launch import roofline
B = 3
part = csrmod.Partition2D(n=1 << 14, n_orig=1 << 14, rows=2, cols=2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
blk = jax.ShapeDtypeStruct((2, 2, 1024), jnp.int32)
for mode in ("raw", "bitmap", "auto", "btfly"):
    stage = (lambda z: z + "[btfly:0]") if mode == "btfly" else (lambda z: z)
    for pol in ("top_down", "bottom_up", "direction_opt"):
        stats = CommStats()
        fn = dbfs.build_bfs(
            mesh, part, dbfs.DistBFSConfig(mode=mode, policy=pol), stats=stats
        )
        compiled = jax.jit(fn).lower(
            blk, blk, jax.ShapeDtypeStruct((B,), jnp.int32)
        ).compile()
        cmp = roofline.compare_comm_stats(stats, compiled.as_text())
        assert cmp.match, (mode, pol, cmp.diff())
        planes, bare = {}, set()
        for z in cmp.per_phase:
            if "@p" in z:
                base, _, k = z.partition("@p")
                planes.setdefault(base, set()).add(int(k))
            else:
                bare.add(z)
        want = {"bfs/column", "bfs/transpose", "bfs/termination"}
        if pol in ("top_down", "direction_opt"):
            want |= {stage("bfs/row")}
        if pol in ("bottom_up", "direction_opt"):
            want |= {stage("bfs/row-pull"), stage("bfs/unreached")}
        assert set(planes) == want, (mode, pol, sorted(planes))
        assert all(ks == set(range(B)) for ks in planes.values()), (mode, pol, planes)
        assert "bfs/degree" not in planes
        assert ("bfs/degree" in bare) == (pol == "direction_opt"), (mode, pol, bare)
        # any other whole-phase entry is a consensus rider on a plane zone
        assert bare - {"bfs/degree"} <= want, (mode, pol, sorted(bare))
print("PLANE PARITY OK")
""",
        devices=4,
    )
    assert "PLANE PARITY OK" in out
