"""Direction-optimizing traversal policies: equivalence + oracle + wires.

The contract: ``bottom_up`` and ``direction_opt`` produce level-identical
(and parent-identical) results to ``top_down`` on arbitrary graphs, across
every wire mode — the directions differ in probe representation and wire
shape only.  The density oracle's popcount equals the plain frontier sum,
and its hysteresis band behaves.
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import registry as wire_registry
from repro.core import bfs, traversal, validate
from repro.graphgen import builder, kronecker

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALT_POLICIES = ("bottom_up", "direction_opt")


def _device_graph(g):
    return jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


def test_policies_registered():
    assert set(wire_registry.available_traversals()) >= set(traversal.POLICIES)
    with pytest.raises(KeyError):
        wire_registry.traversal("sideways")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, 255))
def test_policies_level_identical_random_graphs(seed, root):
    """bottom_up and direction_opt reproduce top_down's parent AND level
    arrays exactly on arbitrary random graphs."""
    rng = np.random.default_rng(seed)
    n = 256
    m = rng.integers(1, 2048)
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    src, dst = _device_graph(g)
    base = bfs.bfs(src, dst, jnp.int32(root), g.n, policy="top_down")
    ref = validate.reference_bfs(g, root)
    np.testing.assert_array_equal(np.asarray(base.level), ref)
    for policy in ALT_POLICIES:
        res = bfs.bfs(src, dst, jnp.int32(root), g.n, policy=policy)
        np.testing.assert_array_equal(np.asarray(res.parent), np.asarray(base.parent))
        np.testing.assert_array_equal(np.asarray(res.level), np.asarray(base.level))
        assert int(res.n_levels) == int(base.n_levels)
        v = validate.validate_bfs_tree(g, np.asarray(res.parent), root, np.asarray(res.level))
        assert v.ok, (policy, v.failures)


@pytest.mark.parametrize("policy", ALT_POLICIES)
def test_bfs_levels_policy_sizes(policy):
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=1), n=256)
    src, dst = _device_graph(g)
    res, sizes = bfs.bfs_levels(src, dst, jnp.int32(0), g.n, max_levels=16, policy=policy)
    n_reached = int((np.asarray(res.level) >= 0).sum())
    assert int(np.asarray(sizes).sum()) + 1 == n_reached


def test_oracle_popcount_matches_sum():
    rng = np.random.default_rng(0)
    # 3000: not a 1024-bit multiple; 33*1024: packed words not a multiple of
    # the popcount kernel's 1024-word block (regression: fallback reshape)
    for n in (3000, 33 * 1024):
        oracle = traversal.DensityOracle(n)
        for density in (0.0, 0.01, 0.5, 1.0):
            bits = jnp.asarray(rng.random(n) < density)
            assert int(oracle.local_count(bits)) == int(np.asarray(bits).sum())


def test_oracle_hysteresis():
    oracle = traversal.DensityOracle(1000, alpha=0.25, beta=0.05)
    # below alpha from top-down: stay top-down
    assert not bool(oracle.next_direction(np.int32(250), False))
    assert bool(oracle.next_direction(np.int32(251), False))
    # inside the hysteresis band from bottom-up: stay bottom-up
    assert bool(oracle.next_direction(np.int32(100), True))
    assert not bool(oracle.next_direction(np.int32(49), True))


def test_ladder_alpha_matches_row_ladder_edge():
    from repro.comm.ladder import BucketLadder

    s, wp = 8192, 16
    ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    assert ladder.specs  # sparse buckets exist at this geometry
    assert traversal.ladder_alpha(s, wp) == ladder.specs[-1].cap / s


def test_direction_opt_beats_top_down_on_dense_level_bench():
    """Acceptance: on the scale-15 2x2 bench, direction_opt selects
    bottom-up on at least one dense level and moves fewer row-phase wire
    bytes there than top_down's ALLTOALLV (the BENCH_comm.json policy
    dimension); the butterfly plan's staged volumes reconcile with the
    static byte model (the same check CI runs via
    scripts/check_bench_comm.py)."""
    import importlib.util

    from benchmarks import bfs_comm

    table, levels = bfs_comm.run(scale=15, rows=2, cols=2)
    td = {d["level"]: d for d in levels["top_down"]}
    bu = [d for d in levels["direction_opt"] if d["direction"] == "bottom_up"]
    assert bu, "direction_opt never selected bottom-up"
    assert any(d["density"] > 0.25 for d in bu)  # a genuinely dense level
    assert any(
        d["row_bytes_packed"] < td[d["level"]]["row_bytes_packed"] for d in bu
    ), (bu, td)
    # the policy AND plan dimensions are present in the table
    pols = {r["policy"] for r in table}
    assert pols == set(traversal.POLICIES)
    assert {r["plan"] for r in table} == set(bfs_comm.PLANS)
    # staged butterfly volumes vs the WirePlan static byte model — exercise
    # the CI checker itself on an in-memory BENCH document
    spec = importlib.util.spec_from_file_location(
        "check_bench_comm", os.path.join(REPO, "scripts", "check_bench_comm.py")
    )
    checker = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(checker)
    n = 1 << 15  # scale 15 on 2x2 needs no extra padding (8 x 4096-chunks)
    doc = {"chunk": n // 4, "n": n, "policy_levels": levels, "table": table}
    assert checker.check(doc) > 0


def test_btfly_schedule_and_byte_model():
    """Stage schedule invariants at every grid width, and the static byte
    model knows every format a stage can choose."""
    from repro.comm import butterfly

    for c in range(1, 9):
        sched = butterfly.ButterflySchedule(c)
        assert sched.p & (sched.p - 1) == 0 and sched.p <= c < 2 * sched.p
        assert sched.extra == c - sched.p
        assert sched.slots == (2 if sched.extra else 1)
        assert 1 << sched.n_stages == sched.p
        # each stage is a pairwise swap of the power-of-two ranks
        for t in range(sched.n_stages):
            perm = sched.stage_perm(t)
            assert sorted(src for src, _ in perm) == list(range(sched.p))
            assert all(dst == src ^ (1 << t) for src, dst in perm)
        # total leaf rows exchanged over all stages = p - 1 (halving series)
        assert sum(sched.stage_blocks(t) for t in range(sched.n_stages)) == sched.p - 1
        # every row chunk maps to exactly one leaf
        leaves = {sched.leaf_of_chunk(q) for q in range(c)}
        assert len(leaves) == c
    s, n = 8192, 1 << 15
    ladder, floor = butterfly.row_wire(s, n)
    assert floor.name == "bitmap+p16"  # 15-bit global ids pack at class 16
    for fmt in ladder.formats():
        assert butterfly.stage_unit_bytes(s, n, fmt.name) == fmt.wire_bytes
    assert butterfly.stage_unit_bytes(s, n, floor.name) == floor.wire_bytes
    assert butterfly.stage_unit_bytes(s, n, "bitmap", zone="unreached") == 4 * (s // 32)
    # the same pfor name prices differently on the two wires (payload)
    col_ladder, _ = butterfly.unreached_wire(1 << 16)
    row_ladder, _ = butterfly.row_wire(1 << 16, 1 << 18)
    shared = {f.name for f in col_ladder.formats()} & {
        f.name for f in row_ladder.formats()
    }
    assert shared and all(
        butterfly.stage_unit_bytes(1 << 16, 1 << 18, nm, zone="row")
        > butterfly.stage_unit_bytes(1 << 16, 1 << 18, nm, zone="unreached")
        for nm in shared
    )
    with pytest.raises(KeyError):
        butterfly.stage_unit_bytes(s, n, "no-such-format")
    # at 32-bit global ids the floor degenerates to the dense vector
    _, floor32 = butterfly.row_wire(8192, 1 << 20)
    assert floor32.name == "dense-i32"


def _run(snippet: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_distributed_policies_all_modes_4dev():
    """Every policy x wire-mode combination matches the host oracle; a low
    alpha forces direction_opt through its bottom-up branch for real."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(10, seed=3), n=1<<10)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2)
ref = validate.reference_bfs(g, 0)
for mode in ("raw", "bitmap", "auto", "btfly"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, alpha=0.01, beta=0.002)
        fn = dbfs.build_bfs(mesh, bg, cfg)
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
        level = np.asarray(level)[:g.n]
        assert np.array_equal(level, ref), (mode, pol)
        assert validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], 0, level).ok
print("DIST POLICIES OK")
""",
        devices=4,
    )
    assert "DIST POLICIES OK" in out


@pytest.mark.slow
def test_comm_stats_match_hlo_btfly_4dev():
    """Tentpole acceptance: every butterfly stage's CommStats entries
    reconcile 1:1 with the collective-permute ops in the lowered HLO, for
    all three policies, on both a 1-stage (C=2) and a 2-stage (C=4) grid;
    the transpose zone's moved bytes undercut its HLO bytes (identity
    ppermute pairs are not wire traffic)."""
    out = _run(
        """
import jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.launch import roofline
for rows, cols, mesh_shape in ((2, 2, (2, 2)), (2, 4, (2, 4))):
    part = csrmod.Partition2D(n=1 << 16, n_orig=1 << 16, rows=rows, cols=cols)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    blk = jax.ShapeDtypeStruct((rows, cols, 4096), jnp.int32)
    n_stages = cols.bit_length() - 1
    for pol in ("top_down", "bottom_up", "direction_opt"):
        stats = CommStats()
        fn = dbfs.build_bfs(mesh, part, dbfs.DistBFSConfig(mode="btfly", policy=pol), stats=stats)
        compiled = jax.jit(fn).lower(blk, blk, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        cmp = roofline.compare_comm_stats(stats, compiled.as_text())
        assert cmp.match, (cols, pol, cmp.diff())
        stages = {f"bfs/row[btfly:{t}]" for t in range(n_stages)}
        want = {"bfs/column", "bfs/transpose", "bfs/termination"}
        if pol == "top_down":
            want |= stages
        elif pol == "bottom_up":
            want |= {z.replace("row[", "row-pull[") for z in stages}
            want |= {f"bfs/unreached[btfly:{t}]" for t in range(n_stages)}
        else:
            want |= stages | {z.replace("row[", "row-pull[") for z in stages}
            want |= {f"bfs/unreached[btfly:{t}]" for t in range(n_stages)}
            want |= {"bfs/degree"}  # anticipatory m_f oracle's one-time psum
        assert set(cmp.per_phase) == want, (cols, pol, sorted(cmp.per_phase))
        moved = stats.per_phase_moved()
        assert moved["bfs/transpose"] < cmp.per_phase["bfs/transpose"]
print("BTFLY COMM STATS MATCH OK")
""",
        devices=8,
    )
    assert "BTFLY COMM STATS MATCH OK" in out


@pytest.mark.slow
def test_btfly_folded_non_power_of_two_6dev():
    """C=3 exercises the folded first stage: the overhang rank's candidates
    fold onto rank 0 before the butterfly and unfold after; results match
    the host oracle for every policy, and the fold/unfold CommStats zones
    reconcile with the HLO."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker
from repro.launch import roofline
g = builder.build_csr(kronecker.kronecker_edges(10, seed=3), n=1<<10)
mesh = jax.make_mesh((2, 3), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=3)
ref = validate.reference_bfs(g, 0)
for pol in ("top_down", "bottom_up", "direction_opt"):
    cfg = dbfs.DistBFSConfig(mode="btfly", policy=pol, alpha=0.01, beta=0.002)
    stats = CommStats()
    fn = dbfs.build_bfs(mesh, bg, cfg, stats=stats)
    src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
    lowered = jax.jit(fn).lower(src_l, dst_l, jnp.int32(0)).compile()
    cmp = roofline.compare_comm_stats(stats, lowered.as_text())
    assert cmp.match, (pol, cmp.diff())
    row_zone = "bfs/row-pull" if pol == "bottom_up" else "bfs/row"
    assert f"{row_zone}[btfly:fold]" in cmp.per_phase, sorted(cmp.per_phase)
    assert f"{row_zone}[btfly:unfold]" in cmp.per_phase
    parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
    level = np.asarray(level)[:g.n]
    assert np.array_equal(level, ref), (pol, np.nonzero(level != ref)[0][:10])
    assert validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], 0, level).ok
print("BTFLY FOLD OK")
""",
        devices=6,
    )
    assert "BTFLY FOLD OK" in out


@pytest.mark.slow
def test_btfly_equals_raw_property_4dev():
    """Satellite acceptance: property test — the btfly plan produces
    parents AND levels identical to mode 'raw' for every policy on random
    graphs (hypothesis drives the graphs; the compiled fns are reused
    across examples because shapes are pinned)."""
    out = _run(
        """
import os, sys
try:
    import hypothesis
except ImportError:
    sys.path.insert(0, os.path.join(r"%s", "tests", "_shims"))
from hypothesis import given, settings
from hypothesis import strategies as st
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder
n = 1 << 10
mesh = jax.make_mesh((2, 2), ("data", "model"))
fns = {}
for mode in ("raw", "btfly"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, alpha=0.01, beta=0.002)
        part = csrmod.Partition2D(n=4096, n_orig=n, rows=2, cols=2)
        fns[mode, pol] = (dbfs.build_bfs(mesh, part, cfg), cfg)

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, (1 << 10) - 1))
def prop(seed, root):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 400))
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    bg = csrmod.partition_2d(g, rows=2, cols=2, e_cap_multiple=1024)
    assert bg.e_cap == 1024  # pinned -> compiled fns are reused
    outs = {}
    for (mode, pol), (fn, cfg) in fns.items():
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(src_l, dst_l, jnp.int32(root))
        outs[mode, pol] = (np.asarray(parent), np.asarray(level))
    for pol in ("top_down", "bottom_up", "direction_opt"):
        np.testing.assert_array_equal(outs["btfly", pol][0], outs["raw", pol][0])
        np.testing.assert_array_equal(outs["btfly", pol][1], outs["raw", pol][1])

prop()
print("BTFLY PROPERTY OK")
""" % REPO,
        devices=4,
        timeout=1200,
    )
    assert "BTFLY PROPERTY OK" in out


@pytest.mark.slow
def test_row_payload_localization_8dev():
    """Regression: at C=4 with n_c=2**15 the packed-parent class (16 bits)
    is narrower than global ids (17 bits) — the sparse push row branch used
    to truncate the high bit.  Payloads now travel column-local and are
    re-globalized from the all-to-all row index."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder
edges = np.array([[0, 70000], [70000, 100]])
g = builder.build_csr(edges, n=1 << 17)
mesh = jax.make_mesh((2, 4), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=4)
for mode in ("auto", "btfly"):
    cfg = dbfs.DistBFSConfig(mode=mode, policy="top_down")
    fn = dbfs.build_bfs(mesh, bg, cfg)
    src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
    parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
    parent = np.asarray(parent)
    assert parent[100] == 70000, (mode, parent[100])
    assert parent[70000] == 0, (mode, parent[70000])
print("PAYLOAD LOCALIZATION OK")
""",
        devices=8,
    )
    assert "PAYLOAD LOCALIZATION OK" in out


@pytest.mark.slow
def test_comm_stats_match_hlo_bottom_up_4dev():
    """Satellite acceptance: the CommStats ledger still matches the lowered
    HLO per op kind for the bottom-up exchanges (found-bitmap row phase +
    unreached all-gather), in every wire mode, for both pull policies."""
    out = _run(
        """
import jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.launch import roofline
part = csrmod.Partition2D(n=1 << 16, n_orig=1 << 16, rows=2, cols=2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
blk = jax.ShapeDtypeStruct((2, 2, 4096), jnp.int32)
for mode in ("raw", "bitmap", "auto"):
    for pol in ("bottom_up", "direction_opt"):
        stats = CommStats()
        fn = dbfs.build_bfs(mesh, part, dbfs.DistBFSConfig(mode=mode, policy=pol), stats=stats)
        compiled = jax.jit(fn).lower(blk, blk, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        cmp = roofline.compare_comm_stats(stats, compiled.as_text())
        assert cmp.match, (mode, pol, cmp.diff())
        want = {"bfs/column", "bfs/row-pull", "bfs/transpose", "bfs/termination", "bfs/unreached"}
        if pol == "direction_opt":
            want |= {"bfs/row", "bfs/degree"}
        assert set(cmp.per_phase) == want, (mode, pol, cmp.per_phase)
print("BU COMM STATS MATCH OK")
""",
        devices=4,
    )
    assert "BU COMM STATS MATCH OK" in out
