"""Direction-optimizing traversal policies: equivalence + oracle + wires.

The contract: ``bottom_up`` and ``direction_opt`` produce level-identical
(and parent-identical) results to ``top_down`` on arbitrary graphs, across
every wire mode — the directions differ in probe representation and wire
shape only.  The density oracle's popcount equals the plain frontier sum,
and its hysteresis band behaves.
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import registry as wire_registry
from repro.core import bfs, traversal, validate
from repro.graphgen import builder, kronecker

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALT_POLICIES = ("bottom_up", "direction_opt")


def _device_graph(g):
    return jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


def test_policies_registered():
    assert set(wire_registry.available_traversals()) >= set(traversal.POLICIES)
    with pytest.raises(KeyError):
        wire_registry.traversal("sideways")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, 255))
def test_policies_level_identical_random_graphs(seed, root):
    """bottom_up and direction_opt reproduce top_down's parent AND level
    arrays exactly on arbitrary random graphs."""
    rng = np.random.default_rng(seed)
    n = 256
    m = rng.integers(1, 2048)
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    src, dst = _device_graph(g)
    base = bfs.bfs(src, dst, jnp.int32(root), g.n, policy="top_down")
    ref = validate.reference_bfs(g, root)
    np.testing.assert_array_equal(np.asarray(base.level), ref)
    for policy in ALT_POLICIES:
        res = bfs.bfs(src, dst, jnp.int32(root), g.n, policy=policy)
        np.testing.assert_array_equal(np.asarray(res.parent), np.asarray(base.parent))
        np.testing.assert_array_equal(np.asarray(res.level), np.asarray(base.level))
        assert int(res.n_levels) == int(base.n_levels)
        v = validate.validate_bfs_tree(g, np.asarray(res.parent), root, np.asarray(res.level))
        assert v.ok, (policy, v.failures)


@pytest.mark.parametrize("policy", ALT_POLICIES)
def test_bfs_levels_policy_sizes(policy):
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=1), n=256)
    src, dst = _device_graph(g)
    res, sizes = bfs.bfs_levels(src, dst, jnp.int32(0), g.n, max_levels=16, policy=policy)
    n_reached = int((np.asarray(res.level) >= 0).sum())
    assert int(np.asarray(sizes).sum()) + 1 == n_reached


def test_oracle_popcount_matches_sum():
    rng = np.random.default_rng(0)
    # 3000: not a 1024-bit multiple; 33*1024: packed words not a multiple of
    # the popcount kernel's 1024-word block (regression: fallback reshape)
    for n in (3000, 33 * 1024):
        oracle = traversal.DensityOracle(n)
        for density in (0.0, 0.01, 0.5, 1.0):
            bits = jnp.asarray(rng.random(n) < density)
            assert int(oracle.local_count(bits)) == int(np.asarray(bits).sum())


def test_oracle_hysteresis():
    oracle = traversal.DensityOracle(1000, alpha=0.25, beta=0.05)
    # below alpha from top-down: stay top-down
    assert not bool(oracle.next_direction(np.int32(250), False))
    assert bool(oracle.next_direction(np.int32(251), False))
    # inside the hysteresis band from bottom-up: stay bottom-up
    assert bool(oracle.next_direction(np.int32(100), True))
    assert not bool(oracle.next_direction(np.int32(49), True))


def test_ladder_alpha_matches_row_ladder_edge():
    from repro.comm.ladder import BucketLadder

    s, wp = 8192, 16
    ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    assert ladder.specs  # sparse buckets exist at this geometry
    assert traversal.ladder_alpha(s, wp) == ladder.specs[-1].cap / s


def test_direction_opt_beats_top_down_on_dense_level_bench():
    """Acceptance: on the scale-15 2x2 bench, direction_opt selects
    bottom-up on at least one dense level and moves fewer row-phase wire
    bytes there than top_down's ALLTOALLV (the BENCH_comm.json policy
    dimension)."""
    from benchmarks import bfs_comm

    table, levels = bfs_comm.run(scale=15, rows=2, cols=2)
    td = {d["level"]: d for d in levels["top_down"]}
    bu = [d for d in levels["direction_opt"] if d["direction"] == "bottom_up"]
    assert bu, "direction_opt never selected bottom-up"
    assert any(d["density"] > 0.25 for d in bu)  # a genuinely dense level
    assert any(
        d["row_bytes_packed"] < td[d["level"]]["row_bytes_packed"] for d in bu
    ), (bu, td)
    # the policy dimension is present in the table for every zone
    pols = {r["policy"] for r in table}
    assert pols == set(traversal.POLICIES)


def _run(snippet: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_distributed_policies_all_modes_4dev():
    """Every policy x wire-mode combination matches the host oracle; a low
    alpha forces direction_opt through its bottom-up branch for real."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(10, seed=3), n=1<<10)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2)
ref = validate.reference_bfs(g, 0)
for mode in ("raw", "bitmap", "auto"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, alpha=0.01, beta=0.002)
        fn = dbfs.build_bfs(mesh, bg, cfg)
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
        level = np.asarray(level)[:g.n]
        assert np.array_equal(level, ref), (mode, pol)
        assert validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], 0, level).ok
print("DIST POLICIES OK")
""",
        devices=4,
    )
    assert "DIST POLICIES OK" in out


@pytest.mark.slow
def test_comm_stats_match_hlo_bottom_up_4dev():
    """Satellite acceptance: the CommStats ledger still matches the lowered
    HLO per op kind for the bottom-up exchanges (found-bitmap row phase +
    unreached all-gather), in every wire mode, for both pull policies."""
    out = _run(
        """
import jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.launch import roofline
part = csrmod.Partition2D(n=1 << 16, n_orig=1 << 16, rows=2, cols=2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
blk = jax.ShapeDtypeStruct((2, 2, 4096), jnp.int32)
for mode in ("raw", "bitmap", "auto"):
    for pol in ("bottom_up", "direction_opt"):
        stats = CommStats()
        fn = dbfs.build_bfs(mesh, part, dbfs.DistBFSConfig(mode=mode, policy=pol), stats=stats)
        compiled = jax.jit(fn).lower(blk, blk, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        cmp = roofline.compare_comm_stats(stats, compiled.as_text())
        assert cmp.match, (mode, pol, cmp.diff())
        want = {"bfs/column", "bfs/row-pull", "bfs/transpose", "bfs/termination", "bfs/unreached"}
        if pol == "direction_opt":
            want |= {"bfs/row"}
        assert set(cmp.per_phase) == want, (mode, pol, cmp.per_phase)
print("BU COMM STATS MATCH OK")
""",
        devices=4,
    )
    assert "BU COMM STATS MATCH OK" in out
