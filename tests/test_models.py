"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finite checks) + model-level invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import common as cfgs
from repro.data import graphs as dgraphs
from repro.models import gnn, irreps as ir, recsys
from repro.models import transformer as tfm

LM_ARCHS = ["deepseek-v2-236b", "dbrx-132b", "minicpm-2b", "gemma-2b", "deepseek-coder-33b"]
GNN_ARCHS = ["graphcast", "gat-cora", "egnn", "nequip"]


def _finite(x):
    return bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train_step(arch):
    cfg = cfgs.get(arch).smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    logits, aux = jax.jit(lambda p, t: tfm.forward(cfg, p, t))(params, toks)
    assert logits.shape == (2, 64, cfg.vocab)
    assert _finite(logits)
    loss, grads = jax.value_and_grad(lambda p: tfm.loss_fn(cfg, p, {"tokens": toks}))(params)
    assert _finite(loss)
    assert all(_finite(g) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_consistency(arch):
    """Greedy decode over a short prompt matches the teacher-forced forward."""
    import dataclasses

    cfg = cfgs.get(arch).smoke_config()
    # fp32 + drop-free capacity so decode must match teacher forcing exactly
    cfg = dataclasses.replace(
        cfg,
        compute_dtype=jnp.float32,
        capacity_factor=8.0 if cfg.is_moe else cfg.capacity_factor,
    )
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    seq = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    ref, _ = jax.jit(lambda p, t: tfm.forward(cfg, p, t))(params, seq)
    cache = tfm.init_cache(cfg, 2, 16)
    dec = jax.jit(lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos))
    logits = None
    for i in range(12):
        logits, cache = dec(params, cache, seq[:, i], jnp.full((2,), i, jnp.int32))
    err = float(jnp.abs(logits - ref[:, -1]).max())
    assert err < 2e-2, err


def test_lm_causality():
    cfg = cfgs.get("minicpm-2b").smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    l1, _ = tfm.forward(cfg, params, toks)
    toks2 = toks.at[:, 50].set((toks[:, 50] + 1) % cfg.vocab)
    l2, _ = tfm.forward(cfg, params, toks2)
    assert bool(jnp.allclose(l1[:, :50], l2[:, :50], atol=2e-2))
    assert not bool(jnp.allclose(l1[:, 50:], l2[:, 50:], atol=1e-4))


def test_blockwise_attention_matches_dense():
    rng = np.random.default_rng(0)
    b, s, h, hd = 2, 96, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, hd)), jnp.float32)
    out = tfm.blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=16)
    # dense reference
    qg = q.reshape(b, s, 2, 2, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * hd**-0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    w = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    ref = jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_moe_router_invariants():
    cfg = cfgs.get("deepseek-v2-236b").smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda p: p[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model), jnp.float32)
    y, aux = tfm._moe_ffn(cfg, lp, x)
    assert y.shape == x.shape and _finite(y)
    assert float(aux) > 0  # load-balance loss is positive


def test_mla_cache_is_compressed():
    cfg = cfgs.get("deepseek-v2-236b").model_config()
    gqa = cfgs.get("deepseek-coder-33b").model_config()
    # MLA latent cache is far smaller than an equivalent-width GQA cache
    assert cfg.cache_width == cfg.kv_lora_rank + cfg.qk_rope_dim
    assert cfg.cache_width < 2 * cfg.n_heads * cfg.head_dim // 8
    assert gqa.cache_width == 2 * gqa.n_kv_heads * gqa.head_dim


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_kind", ["full_graph_sm", "molecule"])
def test_gnn_smoke_all_archs(arch, shape_kind):
    spec = cfgs.get(arch)
    scfg = spec.smoke_config()
    gb = (
        dgraphs.synthetic_graph(200, 800, scfg.d_in, seed=1, n_classes=scfg.d_out)
        if shape_kind == "full_graph_sm"
        else dgraphs.molecule_batch(8, 16, 32, scfg.d_in, seed=1)
    )
    g = gnn.Graph(
        nf=jnp.asarray(gb.nf), src=jnp.asarray(gb.src), dst=jnp.asarray(gb.dst),
        pos=jnp.asarray(gb.pos),
    )
    params = gnn.init(scfg, jax.random.PRNGKey(0))
    out = jax.jit(lambda p, g: gnn.forward(scfg, p, g))(params, g)
    assert out.shape == (g.n, scfg.d_out) and _finite(out)
    tgt = jnp.asarray(np.random.default_rng(0).integers(0, scfg.d_out, g.n), jnp.int32)
    loss, grads = jax.value_and_grad(lambda p: gnn.loss_fn(scfg, p, {"graph": g, "targets": tgt}))(params)
    assert _finite(loss) and all(_finite(x) for x in jax.tree.leaves(grads))


def test_nequip_rotation_invariance():
    scfg = cfgs.get("nequip").smoke_config()
    rng = np.random.default_rng(0)
    n, m = 40, 160
    g1 = gnn.Graph(
        nf=jnp.asarray(rng.normal(size=(n, scfg.d_in)), jnp.float32),
        src=jnp.asarray(rng.integers(0, n, m), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, m), jnp.int32),
        pos=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    )
    theta = 0.7
    rot = jnp.asarray(
        [[np.cos(theta), -np.sin(theta), 0], [np.sin(theta), np.cos(theta), 0], [0, 0, 1]],
        jnp.float32,
    )
    params = gnn.init(scfg, jax.random.PRNGKey(0))
    o1 = gnn.nequip_forward(scfg, params, g1)
    o2 = gnn.nequip_forward(scfg, params, g1._replace(pos=g1.pos @ rot.T))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_egnn_equivariance():
    scfg = cfgs.get("egnn").smoke_config()
    rng = np.random.default_rng(0)
    n, m = 40, 160
    g1 = gnn.Graph(
        nf=jnp.asarray(rng.normal(size=(n, scfg.d_in)), jnp.float32),
        src=jnp.asarray(rng.integers(0, n, m), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, m), jnp.int32),
        pos=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    )
    theta = -1.2
    rot = jnp.asarray(
        [[1, 0, 0], [0, np.cos(theta), -np.sin(theta)], [0, np.sin(theta), np.cos(theta)]],
        jnp.float32,
    )
    shift = jnp.asarray([1.0, -2.0, 0.5])
    params = gnn.init(scfg, jax.random.PRNGKey(0))
    h1, x1 = gnn.egnn_forward(scfg, params, g1)
    h2, x2 = gnn.egnn_forward(scfg, params, g1._replace(pos=g1.pos @ rot.T + shift))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)  # E(n) invariant h
    np.testing.assert_allclose(  # equivariant coordinates
        np.asarray(x1 @ rot.T + shift), np.asarray(x2), atol=1e-4
    )


def test_irreps_product_paths_equivariant():
    rng = np.random.default_rng(1)
    theta = 0.9
    rot = jnp.asarray(
        [[np.cos(theta), -np.sin(theta), 0], [np.sin(theta), np.cos(theta), 0], [0, 0, 1]],
        jnp.float32,
    )
    a = jnp.asarray(rng.normal(size=(5, 2, 3)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(5, 2, 3)), jnp.float32)
    ra, rb = a @ rot.T, b @ rot.T
    np.testing.assert_allclose(np.asarray(ir.p_vv_s(ra, rb)), np.asarray(ir.p_vv_s(a, b)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ir.p_vv_v(ra, rb)), np.asarray(ir.p_vv_v(a, b) @ rot.T), atol=1e-5
    )
    t = ir.p_vv_t(a, b)
    rt = ir.p_vv_t(ra, rb)
    np.testing.assert_allclose(
        np.asarray(rt), np.asarray(jnp.einsum("ik,nckl,jl->ncij", rot, t, rot)), atol=1e-5
    )


def test_graphcast_multimesh():
    from repro.models import icosahedron as ico

    v, e = ico.multimesh(2)
    assert v.shape == (162, 3)
    np.testing.assert_allclose(np.linalg.norm(v, axis=1), 1.0, atol=1e-12)
    assert e.shape[1] == 2 and e.max() < 162
    # multimesh includes coarse-level (level-0) edges between original verts
    lvl0 = ico.faces_to_edges(ico.icosahedron()[1])
    e_set = {tuple(x) for x in e.tolist()}
    assert all(tuple(x) in e_set for x in lvl0.tolist())


def test_autoint_smoke_and_embedding_bag_oracle():
    scfg = cfgs.get("autoint").smoke_config()
    params = recsys.init_params(scfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 256, (16, scfg.n_sparse)), jnp.int32)
    logits = jax.jit(lambda p, i: recsys.forward(scfg, p, i))(params, ids)
    assert logits.shape == (16,) and _finite(logits)
    labels = jnp.asarray(rng.integers(0, 2, 16), jnp.float32)
    loss, grads = jax.value_and_grad(
        lambda p: recsys.loss_fn(scfg, p, {"ids": ids, "labels": labels})
    )(params)
    assert _finite(loss)
    # EmbeddingBag vs one-hot matmul oracle (single + multi-valued bags)
    table = params["table"]
    offs = recsys.field_offsets(scfg)
    got = recsys.embedding_bag(table, ids, offsets=offs)
    onehot = jax.nn.one_hot(ids + offs[None, :], table.shape[0], dtype=table.dtype)
    ref = jnp.einsum("bfr,rd->bfd", onehot, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    bags = ids[:, :, None].repeat(3, -1).at[:, :, 2].set(-1)
    got_bag = recsys.embedding_bag(table, bags, offsets=offs)
    np.testing.assert_allclose(np.asarray(got_bag), np.asarray(2 * ref), atol=1e-5)


def test_autoint_retrieval_is_batched_dot():
    scfg = cfgs.get("autoint").smoke_config()
    params = recsys.init_params(scfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 256, (1, scfg.n_sparse)), jnp.int32)
    cand = jnp.asarray(np.arange(200), jnp.int32)
    scores = recsys.retrieval_scores(scfg, params, ids, cand)
    assert scores.shape == (200,) and _finite(scores)
    uv = recsys.user_vector(scfg, params, ids)[0]
    one = recsys.retrieval_scores(scfg, params, ids, cand[5:6])
    np.testing.assert_allclose(np.asarray(one)[0], float(np.asarray(scores)[5]), rtol=1e-6)
