"""Multi-device integration tests (subprocess with forced host devices).

Each test spawns a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` because the main
pytest process must keep the default single device (dryrun.py rule).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_distributed_bfs_all_modes_4dev():
    out = _run(
        f"import runpy, sys; sys.argv=['x']; "
        f"runpy.run_path(r'{os.path.join(REPO, 'scripts', 'check_dist_bfs.py')}', "
        f"run_name='__main__')"
    )
    assert "DIST BFS ALL MODES OK" in out


@pytest.mark.slow
def test_distributed_bfs_multipod_fold_8dev():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(10, seed=3), n=1<<10)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
bg = csrmod.partition_2d(g, rows=4, cols=2)  # chunk stays a 1024-multiple
cfg = dbfs.DistBFSConfig(row_axes=("pod", "data"), col_axis="model", mode="auto")
fn = dbfs.build_bfs(mesh, bg, cfg)
src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
ref = validate.reference_bfs(g, 0)
assert np.array_equal(np.asarray(level)[:g.n], ref)
res = validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], 0)
assert res.ok, res.failures
print("MULTIPOD OK")
""",
        devices=8,
    )
    assert "MULTIPOD OK" in out


@pytest.mark.slow
def test_gnn_2d_matches_single_device_4dev():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.models import gnn, gnn_dist
from repro.core import csr as csrmod
from repro.graphgen import builder, kronecker
mesh = jax.make_mesh((2, 2), ("data", "model"))
g = builder.build_csr(kronecker.kronecker_edges(9, seed=5), n=1<<9)
bg = csrmod.partition_2d(g, rows=2, cols=2, chunk_multiple=256)
part = bg.part
rng = np.random.default_rng(0)
n, d_in = part.n, 12
nf = rng.normal(size=(n, d_in)).astype(np.float32)
pos = rng.normal(size=(n, 3)).astype(np.float32)
targets = rng.integers(0, 16, n).astype(np.int32)
for cfg in [gnn.GraphCastConfig(n_layers=2, d_hidden=16, d_in=d_in, d_out=16, edge_state=False),
            gnn.GATConfig(n_layers=2, d_hidden=8, n_heads=2, d_in=d_in, d_out=16),
            gnn.EGNNConfig(n_layers=2, d_hidden=16, d_in=d_in, d_out=16),
            gnn.NequIPConfig(n_layers=2, d_hidden=4, d_in=d_in, d_out=16)]:
    params = gnn.init(cfg, jax.random.PRNGKey(0))
    stepf, _ = gnn_dist.build_2d_train_step(mesh, cfg, part, bg.e_cap)
    r, c, s = part.rows, part.cols, part.chunk
    loss, grads = stepf(params, jnp.asarray(nf.reshape(r,c,s,d_in)), jnp.asarray(pos.reshape(r,c,s,3)),
                        jnp.asarray(bg.src_local), jnp.asarray(bg.dst_local), jnp.asarray(targets.reshape(r,c,s)))
    src_g = np.where(bg.src_local < part.n_c, bg.src_local + (np.arange(c)*part.n_c)[None,:,None], n).reshape(-1)
    dst_g = np.where(bg.dst_local < part.n_r, bg.dst_local + (np.arange(r)*part.n_r)[:,None,None], n).reshape(-1)
    gg = gnn.Graph(nf=jnp.asarray(nf), src=jnp.asarray(src_g, dtype=jnp.int32),
                   dst=jnp.asarray(dst_g, dtype=jnp.int32), pos=jnp.asarray(pos))
    ref = gnn.loss_fn(cfg, params, {"graph": gg, "targets": jnp.asarray(targets)})
    assert abs(float(loss) - float(ref)) < 1e-4, (cfg.name, float(loss), float(ref))
print("GNN2D OK")
""",
        devices=4,
    )
    assert "GNN2D OK" in out


@pytest.mark.slow
def test_gnn_2d_int8_payload_4dev():
    """Quantized halo payloads: loss stays close to fp32 and STE gradients
    flow (the beyond-paper int8 wire format for 2D GNN feature exchange)."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.models import gnn, gnn_dist
from repro.core import csr as csrmod
from repro.graphgen import builder, kronecker
mesh = jax.make_mesh((2, 2), ("data", "model"))
g = builder.build_csr(kronecker.kronecker_edges(9, seed=5), n=1<<9)
bg = csrmod.partition_2d(g, rows=2, cols=2, chunk_multiple=256)
part = bg.part
rng = np.random.default_rng(0)
n, d_in = part.n, 12
nf = rng.normal(size=(n, d_in)).astype(np.float32)
pos = rng.normal(size=(n, 3)).astype(np.float32)
targets = rng.integers(0, 16, n).astype(np.int32)
cfg = gnn.GraphCastConfig(n_layers=2, d_hidden=16, d_in=d_in, d_out=16, edge_state=False)
params = gnn.init(cfg, jax.random.PRNGKey(0))
r, c, s = part.rows, part.cols, part.chunk
args = (params, jnp.asarray(nf.reshape(r,c,s,d_in)), jnp.asarray(pos.reshape(r,c,s,3)),
        jnp.asarray(bg.src_local), jnp.asarray(bg.dst_local), jnp.asarray(targets.reshape(r,c,s)))
losses = {}
for q in (False, True):
    dcfg = gnn_dist.Dist2DConfig(quantize_payload=q)
    stepf, _ = gnn_dist.build_2d_train_step(mesh, cfg, part, bg.e_cap, dcfg)
    loss, grads = stepf(*args)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(gn) and gn > 0, (q, loss, gn)
    losses[q] = float(loss)
rel = abs(losses[True] - losses[False]) / abs(losses[False])
assert rel < 0.05, losses  # int8 wire format changes the loss <5%
print("INT8 PAYLOAD OK", losses)
""",
        devices=4,
    )
    assert "INT8 PAYLOAD OK" in out


@pytest.mark.slow
def test_dp_train_int8_ef_4dev():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.optim import adamw
from repro.train import step as tstep
mesh = jax.make_mesh((4,), ("data",))
def loss_fn(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"])**2)
rng = np.random.default_rng(0)
w_true = rng.normal(size=(16,)).astype(np.float32)
state = tstep.init_state({"w": jnp.zeros(16)}, with_ef=True)
ocfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=10_000)
stepf = tstep.make_dp_train_step(loss_fn, ocfg, mesh, compress=True)
for i in range(150):
    x = rng.normal(size=(64, 16)).astype(np.float32)
    state, m = stepf(state, {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)})
assert float(m["loss"]) < 1e-2, float(m["loss"])
print("DP-EF OK", float(m["loss"]))
""",
        devices=4,
    )
    assert "DP-EF OK" in out


@pytest.mark.slow
def test_sparse_packed_branches_execute_4dev():
    """At realistic chunk sizes (s=65536) the ladder has sparse buckets and
    the packed branch of the switch actually executes — correct for every
    density regime (packed buckets AND bitmap fallback)."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import comm as cc
mesh = jax.make_mesh((4,), ("data",))
s = 65536
ladder = cc.BucketLadder.default(s)
assert ladder.specs, "ladder must have sparse buckets at s=65536"
from repro import compat
def gathered(bits):
    f = compat.shard_map(lambda b: cc.allgather_membership(b.reshape(-1), ("data",), ladder, 4),
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
    return jax.jit(f)(bits)
rng = np.random.default_rng(0)
for count_per_rank in (50, 900, 8000, 40000):
    bits_np = np.zeros(4 * s, bool)
    for r in range(4):
        idx = rng.choice(s, count_per_rank, replace=False)
        bits_np[r * s + idx] = True
    out = np.asarray(gathered(jnp.asarray(bits_np))).reshape(4, 4 * s)
    assert all(np.array_equal(row, bits_np) for row in out), count_per_rank
print("SPARSE BRANCHES OK")
""",
        devices=4,
    )
    assert "SPARSE BRANCHES OK" in out


@pytest.mark.slow
def test_bfs_scale18_all_buckets_4dev():
    """End-to-end distributed BFS at scale 18 (s=65536): sparse id-stream
    buckets live in BOTH phases (col [1024]; row [1024,4096,16384]) and the
    result still matches the oracle + Graph500 rules."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro import comm as cc
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(18, seed=3), n=1<<18)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2)
assert cc.BucketLadder.default(bg.part.chunk).specs  # sparse buckets exist
fn = dbfs.build_bfs(mesh, bg, dbfs.DistBFSConfig(mode="auto"))
src_l, dst_l = dbfs.shard_blocked(mesh, bg, dbfs.DistBFSConfig(mode="auto"))
root = int(np.argmax(g.degrees()))
parent, level, depth = fn(src_l, dst_l, jnp.int32(root))
assert np.array_equal(np.asarray(level)[:g.n], validate.reference_bfs(g, root))
assert validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], root).ok
print("SCALE18 OK")
""",
        devices=4,
        timeout=1200,
    )
    assert "SCALE18 OK" in out


@pytest.mark.slow
def test_compressed_allgather_membership_4dev():
    """The bucketed compressed all-gather reproduces plain all-gather for
    sparse AND dense memberships (both switch branches exercised)."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import comm as cc
mesh = jax.make_mesh((4,), ("data",))
s = 2048
ladder = cc.BucketLadder.default(s)
from repro import compat
def gathered(bits):
    f = compat.shard_map(lambda b: cc.allgather_membership(b.reshape(-1), ("data",), ladder, 4),
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
    return jax.jit(f)(bits)
rng = np.random.default_rng(0)
for density in (0.001, 0.02, 0.5):
    bits = jnp.asarray(rng.random(4 * s) < density)
    out = np.asarray(gathered(bits))
    # every device returns the full gathered membership; out_specs P('data')
    # concatenates the 4 identical copies
    got = out.reshape(4, 4 * s)
    ref = np.asarray(bits)
    assert all(np.array_equal(row, ref) for row in got), density
print("CC-ALLGATHER OK")
""",
        devices=4,
    )
    assert "CC-ALLGATHER OK" in out
