"""ELL SpMV kernel (frontier expansion) vs oracles — shape/density sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.bitpack import ref as bpref
from repro.kernels.spmv import ops, pull, ref, spmv


def _python_oracle(nbr, bits, n_cols):
    out = np.full(nbr.shape[0], ref.INF, np.int64)
    for r in range(nbr.shape[0]):
        for d in range(nbr.shape[1]):
            v = nbr[r, d]
            if v < n_cols and bits[v]:
                out[r] = min(out[r], v)
    return out


@pytest.mark.parametrize("n_rows,max_deg", [(1024, 8), (2048, 16), (1024, 32)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_spmv_kernel_sweep(n_rows, max_deg, density):
    n_cols = 4096
    rng = np.random.default_rng(n_rows + max_deg)
    nbr = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    nbr[rng.random((n_rows, max_deg)) < 0.3] = n_cols  # padding
    bits = rng.random(n_cols) < density
    f_words = bpref.pack(jnp.asarray(bits.astype(np.uint32)), 1)
    expect = _python_oracle(nbr, bits, n_cols)
    np.testing.assert_array_equal(
        np.asarray(ref.spmv_min(jnp.asarray(nbr), f_words, n_cols)), expect
    )
    np.testing.assert_array_equal(
        np.asarray(spmv.spmv_min_pallas(jnp.asarray(nbr), f_words, n_cols)), expect
    )
    np.testing.assert_array_equal(
        np.asarray(ops.spmv_min(jnp.asarray(nbr), f_words, n_cols)), expect
    )


@pytest.mark.parametrize("n_rows,max_deg", [(1024, 8), (2048, 16)])
@pytest.mark.parametrize("density,unreached_frac", [(0.05, 0.5), (0.5, 0.1), (0.5, 1.0)])
def test_spmv_pull_kernel_sweep(n_rows, max_deg, density, unreached_frac):
    """Pull direction: unreached rows probe the frontier bitmap, finished
    rows are masked to INF — Pallas kernel vs jnp oracle vs python loop."""
    n_cols = 4096
    rng = np.random.default_rng(n_rows * max_deg + int(100 * density))
    nbr = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    nbr[rng.random((n_rows, max_deg)) < 0.3] = n_cols  # padding
    bits = rng.random(n_cols) < density
    unreached = rng.random(n_rows) < unreached_frac
    f_words = bpref.pack(jnp.asarray(bits.astype(np.uint32)), 1)
    u_words = bpref.pack(jnp.asarray(unreached.astype(np.uint32)), 1)
    expect = np.where(unreached, _python_oracle(nbr, bits, n_cols), ref.INF)
    for fn in (ref.spmv_pull_min, pull.spmv_pull_min_pallas, ops.spmv_pull_min):
        np.testing.assert_array_equal(
            np.asarray(fn(jnp.asarray(nbr), f_words, u_words, n_cols)), expect
        )


@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_spmv_planes_kernel_sweep(density):
    """Multi-source expansion: the plane-blocked push/pull kernels equal the
    single-plane oracle applied per source plane, across ref / Pallas /
    dispatching ops entry points."""
    n_rows, max_deg, n_cols, b = 1024, 16, 4096, 3
    rng = np.random.default_rng(int(density * 100) + 7)
    nbr = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    nbr[rng.random((n_rows, max_deg)) < 0.3] = n_cols  # padding
    bits = rng.random((b, n_cols)) < density
    unreached = rng.random((b, n_rows)) < 0.5
    f_words = jnp.stack(
        [bpref.pack(jnp.asarray(p.astype(np.uint32)), 1) for p in bits]
    )
    u_words = jnp.stack(
        [bpref.pack(jnp.asarray(p.astype(np.uint32)), 1) for p in unreached]
    )
    expect_push = np.stack([_python_oracle(nbr, p, n_cols) for p in bits])
    for fn in (ref.spmv_min_planes, spmv.spmv_min_planes_pallas, ops.spmv_min_planes):
        np.testing.assert_array_equal(
            np.asarray(fn(jnp.asarray(nbr), f_words, n_cols)), expect_push,
            err_msg=str(fn),
        )
    expect_pull = np.where(unreached, expect_push, ref.INF)
    for fn in (
        ref.spmv_pull_min_planes,
        pull.spmv_pull_min_planes_pallas,
        ops.spmv_pull_min_planes,
    ):
        np.testing.assert_array_equal(
            np.asarray(fn(jnp.asarray(nbr), f_words, u_words, n_cols)),
            expect_pull,
            err_msg=str(fn),
        )


def test_spmv_pull_all_reached_is_inf():
    """With every row reached the pull produces no candidates at all."""
    n_rows = n_cols = 1024
    nbr = np.zeros((n_rows, 8), np.int32)  # everyone neighbors vertex 0
    f_words = bpref.pack(jnp.ones(n_cols, jnp.uint32), 1)  # full frontier
    u_words = bpref.pack(jnp.zeros(n_rows, jnp.uint32), 1)  # nobody unreached
    out = np.asarray(pull.spmv_pull_min_pallas(jnp.asarray(nbr), f_words, u_words, n_cols))
    assert (out == ref.INF).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1 << 16))
def test_spmv_matches_segment_min_formulation(seed):
    """The kernel agrees with the segment_min edge-centric formulation used
    by core/bfs.py (same semiring, different data structure)."""
    import jax

    rng = np.random.default_rng(seed)
    n_rows = 1024
    n_cols = 2048
    m = int(rng.integers(1, 4000))
    src = rng.integers(0, n_cols, m).astype(np.int32)
    dst = rng.integers(0, n_rows, m).astype(np.int32)
    bits = rng.random(n_cols) < 0.2
    # edge-centric reference
    cand = np.where(bits[src], src, ref.INF)
    seg = np.full(n_rows, ref.INF, np.int64)
    np.minimum.at(seg, dst, cand)
    # ELL + kernel (max_deg covers the densest row)
    deg = np.bincount(dst, minlength=n_rows).max()
    max_deg = max(int(-(-deg // spmv.DEG_CHUNK) * spmv.DEG_CHUNK), spmv.DEG_CHUNK)
    ell = ref.ell_from_coo(jnp.asarray(src), jnp.asarray(dst), n_rows, n_cols, max_deg)
    f_words = bpref.pack(jnp.asarray(bits.astype(np.uint32)), 1)
    out = np.asarray(spmv.spmv_min_pallas(ell, f_words, n_cols))
    np.testing.assert_array_equal(out, seg)
