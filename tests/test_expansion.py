"""Local-expansion backends: hybrid == ell == coo, plus wire invariance.

The contract has two halves.  *Equivalence*: every expansion backend
produces bit-identical parent/level arrays for every traversal policy,
every wire plan, and batched roots — each row's edge set lives in exactly
one structure (ELL slab or COO residue) and the min-parent semiring
commutes with the split.  *Invariance*: expansion is compute-local, so the
CommStats ledger and the collectives in the lowered HLO must be
byte-identical across backends — a backend that touched the wire would be
a correctness bug in the communication accounting.
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import registry as wire_registry
from repro.core import bfs, expand
from repro.graphgen import builder, kronecker
from repro.kernels.bitpack import ref as bpref
from repro.kernels.spmv import ops as spmv_ops
from repro.kernels.spmv import ref as spmv_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BACKENDS = expand.BACKENDS


def _device_graph(g):
    return jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


def test_backends_registered():
    assert set(wire_registry.available_expansions()) >= set(BACKENDS)
    assert expand.resolve("auto").name == "hybrid"  # the example's alias
    with pytest.raises(KeyError):
        wire_registry.expansion("csr5")
    with pytest.raises(KeyError):
        expand.resolve("csr5")


def test_ell_from_edges_degree_split():
    """Rows at or under the split live entirely in the slab, heavier rows
    entirely in the residue, and the union is exactly the valid edge set."""
    rng = np.random.default_rng(0)
    n_rows, n_cols, m = 64, 48, 400
    src = rng.integers(0, n_cols + 1, m)  # includes sentinel edges
    dst = rng.integers(0, n_rows + 1, m)
    k = 8
    nbr, res_s, res_d = builder.ell_from_edges(src, dst, n_rows, n_cols, k)
    valid = (src < n_cols) & (dst < n_rows)
    deg = np.bincount(dst[valid], minlength=n_rows)
    for r in range(n_rows):
        slab_row = nbr[r][nbr[r] < n_cols]
        want = np.sort(src[valid & (dst == r)])
        if deg[r] <= k:
            np.testing.assert_array_equal(np.sort(slab_row), want)
            assert not (res_d == r).any()
        else:
            assert slab_row.size == 0
            np.testing.assert_array_equal(np.sort(res_s[res_d == r]), want)
    # width override pads, never truncates
    wide, _, _ = builder.ell_from_edges(src, dst, n_rows, n_cols, k, width=k + 8)
    np.testing.assert_array_equal(wide[:, :k], nbr)
    assert (wide[:, k:] == n_cols).all()


def test_select_split_k_waste_budget():
    """The auto selector keeps slab waste under the budget and moves up on
    uniform degrees; a lone hub cannot drag the split to its own degree."""
    uniform = np.full(256, 16)
    assert builder.select_split_k(uniform, waste_budget=0.5) == 16
    skew = np.full(256, 5)
    skew[0] = 200  # hub
    k = builder.select_split_k(skew, waste_budget=0.5)
    assert k < 200
    covered = (skew[skew <= k]).sum()
    assert covered >= 0.5 * skew.size * k  # waste(k) <= 0.5
    # near-empty block: fall back to the minimal slab
    assert builder.select_split_k(np.zeros(128, np.int64)) == 8
    assert builder.select_split_k(np.ones(128, np.int64), waste_budget=0.01) == 8


def test_blocked_containers_cover_every_edge():
    """ELLBlocks/HybridBlocks at partition time: static shapes, sentinels,
    and slab+residue exactly re-covering each block's edges."""
    from repro.core import csr as csrmod

    g = builder.build_csr(kronecker.kronecker_edges(9, seed=2), n=1 << 9)
    bg = csrmod.partition_2d(g, rows=2, cols=2)
    part = bg.part
    ell = csrmod.ell_blocked(bg)
    hyb = csrmod.hybrid_blocked(bg)
    assert ell.nbr.shape[:3] == (2, 2, part.n_r) and ell.k % 8 == 0
    assert hyb.nbr.shape[:3] == (2, 2, part.n_r) and hyb.k % 8 == 0
    assert hyb.res_src.shape == hyb.res_dst.shape == (2, 2, hyb.r_cap)
    assert hyb.k <= ell.k  # the split never exceeds the max degree
    assert (hyb.padding_ratio() <= ell.padding_ratio() + 1e-9).all()
    for i in range(2):
        for j in range(2):
            s_l, d_l = bg.src_local[i, j], bg.dst_local[i, j]
            valid = (s_l < part.n_c) & (d_l < part.n_r)
            want = set(zip(s_l[valid].tolist(), d_l[valid].tolist()))
            for blocks in (ell, hyb):
                rows, slots = np.nonzero(blocks.nbr[i, j] < part.n_c)
                got = set(zip(blocks.nbr[i, j][rows, slots].tolist(), rows.tolist()))
                if hasattr(blocks, "res_src"):
                    rs, rd = blocks.res_src[i, j], blocks.res_dst[i, j]
                    rv = rs < part.n_c
                    got |= set(zip(rs[rv].tolist(), rd[rv].tolist()))
                assert got == want, (i, j, type(blocks).__name__)


def _python_spmv_oracle(nbr, bits, n_cols):
    out = np.full(nbr.shape[0], spmv_ref.INF, np.int64)
    for r in range(nbr.shape[0]):
        for d in range(nbr.shape[1]):
            v = nbr[r, d]
            if v < n_cols and bits[v]:
                out[r] = min(out[r], v)
    return out


def test_spmv_ops_pad_misaligned_shapes():
    """Satellite regression: the ops dispatch used to fall silently to the
    interpret-speed reference on any block off the ROW_TILE/DEG_CHUNK
    multiples — it now pads rows (sentinel neighbor lists) and degree
    (sentinel slots) and slices the output.  ``interpret=True`` forces the
    Pallas path so the padding wrapper is exercised off-TPU too."""
    n_rows, max_deg, n_cols = 1500, 9, 2048  # deliberately misaligned
    rng = np.random.default_rng(7)
    nbr = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    nbr[rng.random((n_rows, max_deg)) < 0.3] = n_cols
    bits = rng.random(n_cols) < 0.2
    unreached = rng.random(n_rows) < 0.5
    f_words = bpref.pack(jnp.asarray(bits.astype(np.uint32)), 1)
    u_bits = np.zeros(2048, np.uint32)  # chunk-padded unreached bitmap
    u_bits[:n_rows] = unreached
    u_words = bpref.pack(jnp.asarray(u_bits), 1)
    expect = _python_spmv_oracle(nbr, bits, n_cols)
    out = spmv_ops.spmv_min(jnp.asarray(nbr), f_words, n_cols, interpret=True)
    assert out.shape == (n_rows,)
    np.testing.assert_array_equal(np.asarray(out), expect)
    out = spmv_ops.spmv_pull_min(
        jnp.asarray(nbr), f_words, u_words, n_cols, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.where(unreached, expect, spmv_ref.INF)
    )
    # plane-batched entry points pad the same way
    outp = spmv_ops.spmv_min_planes(
        jnp.asarray(nbr), f_words[None], n_cols, interpret=True
    )
    assert outp.shape == (1, n_rows)
    np.testing.assert_array_equal(np.asarray(outp[0]), expect)
    outp = spmv_ops.spmv_pull_min_planes(
        jnp.asarray(nbr), f_words[None], u_words[None], n_cols, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(outp[0]), np.where(unreached, expect, spmv_ref.INF)
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, 299),
       skewed=st.booleans())
def test_single_device_backends_identical(seed, root, skewed):
    """hybrid == ell == coo parent AND level planes on the single-device
    driver, for every policy, on both uniform random and degree-skewed
    graphs (n off the 1024 alignment on purpose)."""
    rng = np.random.default_rng(seed)
    n = 300
    if skewed:
        m = int(rng.integers(1, 1500))
        # hub-heavy: half the endpoints land on a few vertices
        hubs = rng.integers(0, 4, size=(m, 2))
        rand = rng.integers(0, n, size=(m, 2))
        pick = rng.random((m, 2)) < 0.5
        edges = np.where(pick, hubs, rand)
    else:
        edges = rng.integers(0, n, size=(int(rng.integers(1, 1500)), 2))
    g = builder.build_csr(edges, n=n)
    src, dst = _device_graph(g)
    for policy in ("top_down", "bottom_up", "direction_opt"):
        base = bfs.bfs(src, dst, jnp.int32(root), g.n, policy=policy)
        for backend in ("ell", "hybrid"):
            res = bfs.bfs(src, dst, jnp.int32(root), g.n, policy=policy,
                          expand=backend)
            np.testing.assert_array_equal(
                np.asarray(res.parent), np.asarray(base.parent),
                err_msg=f"{policy}/{backend}",
            )
            np.testing.assert_array_equal(
                np.asarray(res.level), np.asarray(base.level),
                err_msg=f"{policy}/{backend}",
            )


def test_single_device_batched_backends_identical():
    g = builder.build_csr(kronecker.kronecker_edges(9, seed=5), n=1 << 9)
    src, dst = _device_graph(g)
    roots = bfs.hub_roots(g.degrees(), 3)
    base = bfs.bfs(src, dst, roots, g.n, policy="direction_opt")
    for backend in ("ell", "hybrid", "auto"):
        res = bfs.bfs(src, dst, roots, g.n, policy="direction_opt",
                      expand=backend)
        np.testing.assert_array_equal(np.asarray(res.parent), np.asarray(base.parent))
        np.testing.assert_array_equal(np.asarray(res.level), np.asarray(base.level))


def test_build_bfs_rejects_unknown_backend_and_bad_arity():
    import jax

    from repro.core import csr as csrmod, distributed_bfs as dbfs

    g = builder.build_csr(kronecker.kronecker_edges(8, seed=1), n=256)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bg = csrmod.partition_2d(g, rows=1, cols=1)
    with pytest.raises(KeyError, match="expansion"):
        dbfs.build_bfs(mesh, bg, dbfs.DistBFSConfig(expand="csr5"))
    cfg = dbfs.DistBFSConfig(mode="raw", expand="hybrid")
    fn = dbfs.build_bfs(mesh, bg, cfg)
    blocks = dbfs.shard_blocked(mesh, bg, cfg)
    assert len(blocks) == 5  # src, dst, slab, residue src/dst
    with pytest.raises(TypeError, match="shard_blocked"):
        fn(blocks[0], blocks[1], jnp.int32(0))  # COO arity with hybrid cfg


def _run(snippet: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_dist_backends_all_plans_policies_batched_4dev():
    """Tentpole acceptance: hybrid produces bit-identical parents/levels to
    coo across all 4 wire plans x 3 policies with batched roots on a
    hub-heavy Kronecker graph (ell rides along on the cheapest plan)."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import bfs as bfsmod, csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(9, seed=3), n=1 << 9)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2)
roots = jnp.asarray(bfsmod.hub_roots(g.degrees(), 2).astype(np.int32))
for mode in ("raw", "bitmap", "auto", "btfly"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        outs = {}
        backends = ("coo", "hybrid", "ell") if mode == "raw" else ("coo", "hybrid")
        for backend in backends:
            cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, expand=backend,
                                     alpha=0.01, beta=0.002)
            fn = dbfs.build_bfs(mesh, bg, cfg)
            blocks = dbfs.shard_blocked(mesh, bg, cfg)
            parent, level, depth = fn(*blocks, roots)
            outs[backend] = (np.asarray(parent), np.asarray(level))
        for backend in backends[1:]:
            np.testing.assert_array_equal(outs[backend][0], outs["coo"][0],
                                          err_msg=f"{mode}/{pol}/{backend}")
            np.testing.assert_array_equal(outs[backend][1], outs["coo"][1],
                                          err_msg=f"{mode}/{pol}/{backend}")
print("DIST BACKENDS ALL PLANS OK")
""",
        devices=4,
    )
    assert "DIST BACKENDS ALL PLANS OK" in out


@pytest.mark.slow
def test_dist_backends_equivalence_property_4dev():
    """Satellite acceptance: hypothesis property — hybrid == ell == coo on
    random degree-skewed and uniform graphs, every policy, C=2 grid."""
    out = _run(
        """
import os, sys
try:
    import hypothesis
except ImportError:
    sys.path.insert(0, os.path.join(r"%s", "tests", "_shims"))
from hypothesis import given, settings
from hypothesis import strategies as st
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder
n = 1 << 9
mesh = jax.make_mesh((2, 2), ("data", "model"))

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, (1 << 9) - 1),
       skewed=st.booleans())
def prop(seed, root, skewed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 800))
    if skewed:
        hubs = rng.integers(0, 3, size=(m, 2))
        rand = rng.integers(0, n, size=(m, 2))
        edges = np.where(rng.random((m, 2)) < 0.5, hubs, rand)
    else:
        edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    bg = csrmod.partition_2d(g, rows=2, cols=2, e_cap_multiple=1024)
    outs = {}
    for backend in ("coo", "ell", "hybrid"):
        for pol in ("top_down", "bottom_up", "direction_opt"):
            cfg = dbfs.DistBFSConfig(mode="auto", policy=pol, expand=backend,
                                     alpha=0.01, beta=0.002)
            fn = dbfs.build_bfs(mesh, bg, cfg)
            blocks = dbfs.shard_blocked(mesh, bg, cfg)
            parent, level, depth = fn(*blocks, jnp.int32(root))
            outs[backend, pol] = (np.asarray(parent), np.asarray(level))
    for pol in ("top_down", "bottom_up", "direction_opt"):
        for backend in ("ell", "hybrid"):
            np.testing.assert_array_equal(outs[backend, pol][0], outs["coo", pol][0])
            np.testing.assert_array_equal(outs[backend, pol][1], outs["coo", pol][1])

prop()
print("BACKEND PROPERTY OK")
""" % REPO,
        devices=4,
        timeout=1800,
    )
    assert "BACKEND PROPERTY OK" in out


@pytest.mark.slow
def test_dist_backends_c3_grid_6dev():
    """Non-power-of-two C=3 grid (folded butterfly stages included):
    every backend matches the host oracle for every policy."""
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker
g = builder.build_csr(kronecker.kronecker_edges(9, seed=3), n=1 << 9)
mesh = jax.make_mesh((2, 3), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=3)
ref = validate.reference_bfs(g, 0)
for mode in ("auto", "btfly"):
    for pol in ("top_down", "bottom_up", "direction_opt"):
        for backend in ("ell", "hybrid"):
            cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, expand=backend,
                                     alpha=0.01, beta=0.002)
            fn = dbfs.build_bfs(mesh, bg, cfg)
            blocks = dbfs.shard_blocked(mesh, bg, cfg)
            parent, level, depth = fn(*blocks, jnp.int32(0))
            level = np.asarray(level)[:g.n]
            assert np.array_equal(level, ref), (mode, pol, backend)
            assert validate.validate_bfs_tree(g, np.asarray(parent)[:g.n], 0, level).ok
print("C3 BACKENDS OK")
""",
        devices=6,
    )
    assert "C3 BACKENDS OK" in out


@pytest.mark.slow
def test_commstats_and_hlo_invariant_across_backends_4dev():
    """Tentpole acceptance: expansion is compute-local — the CommStats
    ledger is byte-identical across backends (phase, fmt, collective,
    part, nbytes all equal), every ledger reconciles 1:1 with its lowered
    HLO, and the per-collective HLO byte totals are identical across
    backends for both the direct and the butterfly plan."""
    out = _run(
        """
import jax, jax.numpy as jnp
import numpy as np
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs
from repro.graphgen import builder, kronecker
from repro.launch import roofline
g = builder.build_csr(kronecker.kronecker_edges(9, seed=3), n=1 << 9)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2)
roots = jax.ShapeDtypeStruct((2,), jnp.int32)
for mode in ("auto", "btfly"):
    ledgers, per_op = {}, {}
    for backend in ("coo", "ell", "hybrid"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy="direction_opt", expand=backend)
        stats = CommStats()
        fn = dbfs.build_bfs(mesh, bg, cfg, stats=stats)
        blocks = dbfs.shard_blocked(mesh, bg, cfg)
        structs = [jax.ShapeDtypeStruct(b.shape, b.dtype) for b in blocks]
        compiled = jax.jit(fn).lower(*structs, roots).compile()
        cmp = roofline.compare_comm_stats(stats, compiled.as_text())
        assert cmp.match, (mode, backend, cmp.diff())
        ledgers[backend] = [
            (r.phase, r.fmt, r.collective, r.part, r.nbytes)
            for r in stats.records()
        ]
        per_op[backend] = cmp.per_phase
    assert ledgers["coo"] == ledgers["ell"] == ledgers["hybrid"], mode
    assert per_op["coo"] == per_op["ell"] == per_op["hybrid"], mode
print("BACKEND INVARIANCE OK")
""",
        devices=4,
    )
    assert "BACKEND INVARIANCE OK" in out
