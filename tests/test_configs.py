"""Config registry: every assigned arch present, exact spec values."""

import pytest

from repro.configs import common as cfgs

ASSIGNED = [
    "deepseek-v2-236b", "dbrx-132b", "minicpm-2b", "gemma-2b",
    "deepseek-coder-33b", "graphcast", "gat-cora", "egnn", "nequip", "autoint",
]


def test_all_assigned_archs_registered():
    archs = cfgs.list_archs()
    for a in ASSIGNED:
        assert a in archs, a
    assert "graph500" in archs  # the paper's own


def test_every_arch_has_full_shape_set():
    for a in ASSIGNED:
        spec = cfgs.get(a)
        assert len(spec.shapes) == 4, a
        assert callable(spec.smoke_config)


def test_deepseek_v2_exact_values():
    c = cfgs.get("deepseek-v2-236b").model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128, 102400)
    assert (c.n_experts, c.top_k, c.n_shared_experts, c.d_ff_expert) == (160, 6, 2, 1536)
    assert (c.use_mla, c.kv_lora_rank) == (True, 512)
    # ~236B total, ~21B active (paper's numbers)
    assert 200e9 < c.n_params() < 260e9, c.n_params()
    assert 15e9 < c.n_active_params() < 30e9


def test_dbrx_exact_values():
    c = cfgs.get("dbrx-132b").model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 6144, 48, 8)
    assert (c.n_experts, c.top_k, c.d_ff_expert, c.vocab) == (16, 4, 10752, 100352)
    assert 110e9 < c.n_params() < 145e9
    assert 30e9 < c.n_active_params() < 45e9


def test_dense_lm_param_counts():
    c = cfgs.get("minicpm-2b").model_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (40, 2304, 36, 5760, 122753)
    assert 2e9 < c.n_params() < 4e9
    g = cfgs.get("gemma-2b").model_config()
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.head_dim) == (18, 2048, 8, 1, 256)
    assert (g.d_ff, g.vocab, g.act) == (16384, 256000, "gelu")
    assert 2e9 < g.n_params() < 3.5e9
    d = cfgs.get("deepseek-coder-33b").model_config()
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads, d.d_ff, d.vocab) == (
        62, 7168, 56, 8, 19200, 32256,
    )
    assert 30e9 < d.n_params() < 37e9


def test_gnn_config_values():
    gc = cfgs.get("graphcast").model_config()
    assert (gc.n_layers, gc.d_hidden, gc.mesh_refinement, gc.d_in) == (16, 512, 6, 227)
    gat = cfgs.get("gat-cora").model_config()
    assert (gat.n_layers, gat.d_hidden, gat.n_heads) == (2, 8, 8)
    eg = cfgs.get("egnn").model_config()
    assert (eg.n_layers, eg.d_hidden) == (4, 64)
    nq = cfgs.get("nequip").model_config()
    assert (nq.n_layers, nq.d_hidden, nq.l_max, nq.n_rbf, nq.cutoff) == (5, 32, 2, 8, 5.0)


def test_autoint_config_values():
    c = cfgs.get("autoint").model_config()
    assert (c.n_sparse, c.embed_dim, c.n_attn_layers, c.n_heads, c.d_attn) == (
        39, 16, 3, 2, 32,
    )
    assert c.total_rows > 100e6  # multi-million-row tables
    assert c.total_rows % 4096 == 0  # shards evenly on any production mesh


@pytest.mark.parametrize("arch", ASSIGNED)
def test_skip_rules(arch):
    spec = cfgs.get(arch)
    skips = [s for s in spec.shapes if s.kind == "skip"]
    if spec.family == "lm":
        assert [s.name for s in skips] == ["long_500k"]
        assert "sub-quadratic" in skips[0].skip_reason
    else:
        assert not skips
