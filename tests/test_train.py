"""Training runtime: checkpoint atomicity/async/elastic, watchdog, data."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import recsys as drecsys
from repro.data import tokens as dtokens
from repro.optim import adamw
from repro.train import checkpoint, fault
from repro.train import step as tstep


def _toy_state():
    params = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((2, 2))}}
    return tstep.init_state(params)


def test_checkpoint_roundtrip(tmp_path):
    st = _toy_state()
    d = str(tmp_path)
    checkpoint.save(st, 7, d)
    assert checkpoint.latest_step(d) == 7
    restored = checkpoint.restore(st, 7, d)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_ignores_torn_writes(tmp_path):
    st = _toy_state()
    d = str(tmp_path)
    checkpoint.save(st, 3, d)
    # simulate a torn write: .tmp dir left behind + manifest missing status
    os.makedirs(os.path.join(d, "step_000009.tmp"))
    os.makedirs(os.path.join(d, "step_000010"))
    with open(os.path.join(d, "step_000010", "MANIFEST.json"), "w") as f:
        json.dump({"step": 10, "status": "writing"}, f)
    assert checkpoint.latest_step(d) == 3


def test_async_checkpointer_supersedes(tmp_path):
    st = _toy_state()
    ac = checkpoint.AsyncCheckpointer(str(tmp_path))
    for step in (1, 2, 3):
        ac.submit(st, step)
    ac.wait()
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_elastic_restore_resharding(tmp_path):
    """Restore onto a different sharding than the saver used."""
    st = _toy_state()
    d = str(tmp_path)
    checkpoint.save(st, 1, d)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), st
    )
    restored = checkpoint.restore_sharded(st, 1, d, sh)
    np.testing.assert_array_equal(np.asarray(restored.params["w"]), np.arange(8.0))


def test_resume_or_init(tmp_path):
    d = str(tmp_path)
    st, start = fault.resume_or_init(_toy_state, d)
    assert start == 0
    checkpoint.save(st, 5, d)
    st2, start2 = fault.resume_or_init(_toy_state, d)
    assert start2 == 6


def test_watchdog_straggler_detection():
    import time

    dog = fault.StepWatchdog(straggler_factor=3.0)
    for _ in range(8):
        dog.start()
        time.sleep(0.005)
        assert dog.stop() == "ok"
    dog.start()
    time.sleep(0.1)
    assert dog.stop() == "straggler"
    assert dog.stragglers == [8]


def test_token_pipeline_deterministic_and_restart_exact():
    cfg = dtokens.TokenPipelineConfig(vocab=1000, batch=4, seq_len=32, seed=3)
    a = dtokens.batch_at(cfg, 17)["tokens"]
    b = dtokens.batch_at(cfg, 17)["tokens"]
    np.testing.assert_array_equal(a, b)
    # a loader started at step k replays exactly batch_at(k), batch_at(k+1)...
    dl = dtokens.DoubleBufferedLoader(cfg, start_step=5)
    got5, got6 = next(dl), next(dl)
    dl.close()
    np.testing.assert_array_equal(got5["tokens"], dtokens.batch_at(cfg, 5)["tokens"])
    np.testing.assert_array_equal(got6["tokens"], dtokens.batch_at(cfg, 6)["tokens"])


def test_clicklog_deterministic_in_range():
    cfg = drecsys.ClickLogConfig(table_sizes=(100, 50, 1000), batch=64, seed=1)
    b1, b2 = drecsys.batch_at(cfg, 9), drecsys.batch_at(cfg, 9)
    np.testing.assert_array_equal(b1["ids"], b2["ids"])
    assert (b1["ids"] >= 0).all()
    assert (b1["ids"] < np.array([100, 50, 1000])[None, :]).all()
    assert set(np.unique(b1["labels"])) <= {0.0, 1.0}


def test_train_step_decreases_loss_lm():
    """End-to-end: a tiny LM fits the synthetic copy-structured stream."""
    from repro.configs import common as cfgs
    from repro.models import transformer as tfm
    import functools

    cfg = cfgs.get("minicpm-2b").smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=10_000)
    step_fn = jax.jit(tstep.make_train_step(functools.partial(tfm.loss_fn, cfg), opt_cfg))
    state = tstep.init_state(params)
    pipe = dtokens.TokenPipelineConfig(vocab=cfg.vocab, batch=4, seq_len=64, seed=0)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in dtokens.batch_at(pipe, step).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
