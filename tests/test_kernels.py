"""Pallas kernels vs pure-jnp oracles: shape x width x dtype sweeps
(interpret mode executes the kernel body on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.bitpack import bitpack, ops as bpops, ref as bpref
from repro.kernels.popcount import ops as pcops, popcount, ref as pcref
from repro.kernels.quant import quant, ref as qref


@pytest.mark.parametrize("b", bpref.B_CLASSES)
@pytest.mark.parametrize("n_blocks", [1, 2, 5])
def test_bitpack_pallas_matches_ref(b, n_blocks):
    n = n_blocks * bitpack.VALS_PER_BLOCK
    rng = np.random.default_rng(b * 100 + n_blocks)
    hi = (1 << b) if b < 32 else (1 << 32)
    vals = rng.integers(0, hi, size=n, dtype=np.uint64).astype(np.uint32)
    v = jnp.asarray(vals)
    ref_words = bpref.pack(v, b)
    pal_words = bitpack.pack_pallas(v, b)
    np.testing.assert_array_equal(np.asarray(pal_words), np.asarray(ref_words))
    np.testing.assert_array_equal(np.asarray(bitpack.unpack_pallas(pal_words, b)), vals)
    np.testing.assert_array_equal(np.asarray(bpref.unpack(ref_words, b)), vals)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from(bpref.B_CLASSES),
    count=st.integers(0, 4096),
    seed=st.integers(0, 1 << 16),
)
def test_sorted_id_stream_roundtrip_property(b, count, seed):
    """Fused delta+pack/unpack+cumsum is exact for any sorted stream whose
    gaps fit the width class."""
    cap = 4096
    rng = np.random.default_rng(seed)
    max_gap = (1 << b) - 1 if b < 32 else (1 << 20)
    gaps = rng.integers(0, max(max_gap, 1) + 1, size=count)
    ids = np.cumsum(gaps).astype(np.int32)
    padded = np.zeros(cap, np.int32)
    padded[:count] = ids
    words = bpops.pack_sorted_ids(jnp.asarray(padded), jnp.int32(count), b)
    back = bpops.unpack_sorted_ids(words, jnp.int32(count), b, fill=-1)
    np.testing.assert_array_equal(np.asarray(back)[:count], ids)
    assert np.all(np.asarray(back)[count:] == -1)


def test_required_width_class():
    gaps = jnp.asarray(np.array([0, 1, 3], np.uint32))
    assert bpref.B_CLASSES[int(bpref.required_width_class(gaps))] == 2
    gaps = jnp.asarray(np.array([0, 300], np.uint32))
    assert bpref.B_CLASSES[int(bpref.required_width_class(gaps))] == 16


@pytest.mark.parametrize("rows", [1, 3])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_quant_pallas_matches_ref(rows, dtype):
    n = rows * quant.ROWS * qref.GROUP
    rng = np.random.default_rng(rows)
    x = (rng.normal(size=n) * 10).astype(dtype)
    q_ref, s_ref = qref.quantize(jnp.asarray(x))
    q_pal, s_pal = quant.quantize_pallas(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(q_pal), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1 << 16), scale=st.floats(1e-3, 1e3))
def test_quant_error_bound_property(seed, scale):
    """Dequantized values are within scale/2 = maxabs/254 per 128-group."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=qref.GROUP * 4) * scale).astype(np.float32)
    q, s = qref.quantize(jnp.asarray(x))
    xd = np.asarray(qref.dequantize(q, s))
    bound = np.repeat(np.asarray(s), qref.GROUP) / 2 + 1e-12
    assert np.all(np.abs(xd - x) <= bound)


def test_popcount_matches_python():
    rng = np.random.default_rng(0)
    words = rng.integers(0, 1 << 32, size=2048, dtype=np.uint64).astype(np.uint32)
    expect = np.array([bin(int(w)).count("1") for w in words])
    np.testing.assert_array_equal(np.asarray(pcref.popcount_words(jnp.asarray(words))), expect)
    blocks = np.asarray(popcount.popcount_blocks_pallas(jnp.asarray(words)))
    np.testing.assert_array_equal(blocks, expect.reshape(2, 1024).sum(1))
    np.testing.assert_array_equal(
        np.asarray(pcops.popcount_blocks(jnp.asarray(words))), expect.reshape(2, 1024).sum(1)
    )


def test_popcount_planes_matches_per_plane_blocks():
    """The plane-blocked kernel (one grid over B x words) equals the
    single-plane kernel applied per source, including the zero-padding path
    for word counts off the 1024-word block."""
    rng = np.random.default_rng(1)
    words = rng.integers(0, 1 << 32, size=(3, 2048), dtype=np.uint64).astype(np.uint32)
    per_plane = np.stack(
        [np.asarray(popcount.popcount_blocks_pallas(jnp.asarray(p))) for p in words]
    )
    np.testing.assert_array_equal(
        np.asarray(popcount.popcount_planes_pallas(jnp.asarray(words))), per_plane
    )
    totals = per_plane.sum(axis=1)
    np.testing.assert_array_equal(
        np.asarray(pcops.popcount_planes(jnp.asarray(words))), totals
    )
    # unaligned word count -> ops pads each plane to the block geometry
    np.testing.assert_array_equal(
        np.asarray(pcops.popcount_planes(jnp.asarray(words[:, :100]))),
        np.stack([np.asarray(pcref.popcount_words(jnp.asarray(p))).sum() for p in words[:, :100]]),
    )


@pytest.mark.parametrize("b", [1, 16])
def test_bitpack_planes_roundtrip(b):
    """(B, n) plane matrices pack/unpack through the chunk-aligned flatten
    losslessly — the layout the multi-source frontier bitmaps ride."""
    n = 2 * bitpack.VALS_PER_BLOCK
    rng = np.random.default_rng(b)
    hi = 1 << b
    vals = rng.integers(0, hi, size=(3, n), dtype=np.uint64).astype(np.uint32)
    words = bpops.pack_planes(jnp.asarray(vals), b)
    assert words.shape == (3, n * b // 32)
    per_plane = np.stack([np.asarray(bpref.pack(jnp.asarray(p), b)) for p in vals])
    np.testing.assert_array_equal(np.asarray(words), per_plane)
    np.testing.assert_array_equal(np.asarray(bpops.unpack_planes(words, b)), vals)


def test_compact_ids():
    mask = jnp.asarray(np.array([0, 1, 1, 0, 1, 0, 0, 1], bool))
    ids, count = bpops.compact_ids(mask, capacity=8, fill=8)
    assert int(count) == 4
    np.testing.assert_array_equal(np.asarray(ids)[:4], [1, 2, 4, 7])
    assert np.all(np.asarray(ids)[4:] == 8)
