"""End-to-end behaviour tests: the full Graph500 pipeline + graph generation."""

import numpy as np
import jax.numpy as jnp

from repro.core import bfs, validate
from repro.graphgen import builder, kronecker, zipf


def test_graph500_pipeline_end_to_end():
    """Alg. 1: generate -> Kernel 1 (CSR) -> Kernel 2 (BFS) x roots ->
    validate each tree -> TEPS numerators positive."""
    scale = 9
    edges = kronecker.kronecker_edges(scale, seed=7)
    g = builder.build_csr(edges, n=1 << scale)
    rng = np.random.default_rng(0)
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    deg = g.degrees()
    roots = rng.choice(np.nonzero(deg > 0)[0], size=4, replace=False)
    for root in roots:
        res = bfs.bfs(src, dst, jnp.int32(int(root)), g.n)
        v = validate.validate_bfs_tree(
            g, np.asarray(res.parent), int(root), np.asarray(res.level)
        )
        assert v.ok, (root, v.failures)
        assert validate.traversed_edges(g, np.asarray(res.parent)) > 0


def test_kronecker_statistics():
    """Generator honors the Graph500 contract: n = 2^scale, m = n * ef,
    power-law-ish degree skew."""
    scale, ef = 12, 16
    e = kronecker.kronecker_edges(scale, edgefactor=ef, seed=1)
    assert e.shape == ((1 << scale) * ef, 2)
    assert e.min() >= 0 and e.max() < (1 << scale)
    g = builder.build_csr(e, n=1 << scale)
    deg = g.degrees()
    # RMAT skew: max degree far above mean; some isolated vertices exist
    assert deg.max() > 10 * deg.mean()
    assert (deg == 0).sum() > 0


def test_vertex_sorting_improves_gap_statistics():
    """Paper §3.1: degree relabeling concentrates frontier ids near zero,
    shrinking gaps (what the delta codec exploits)."""
    e = kronecker.kronecker_edges(10, seed=2)
    g = builder.build_csr(e, n=1 << 10)
    g2, perm = builder.relabel_by_degree(g)
    assert g2.m == g.m
    deg2 = g2.degrees()
    assert deg2[0] == g.degrees().max()  # highest degree vertex is id 0
    # neighborhoods of hubs now have smaller ids on average
    assert g2.col_idx[: g2.row_ptr[1]].mean() < g.n / 2


def test_csr_builder_symmetry_dedup():
    edges = np.array([[0, 1], [1, 0], [0, 1], [2, 2], [1, 2]])
    g = builder.build_csr(edges, n=3)
    # self-loop dropped, duplicates deduped, symmetric
    assert g.m == 4  # (0,1),(1,0),(1,2),(2,1)
    assert set(map(tuple, np.stack([g.src, g.dst], 1).tolist())) == {
        (0, 1), (1, 0), (1, 2), (2, 1),
    }


def test_zipf_streams():
    s = zipf.zipf_stream(5000, alpha=1.3, vocab=1 << 12, seed=0)
    assert s.dtype == np.uint32 and s.shape == (5000,)
    ids = zipf.sorted_id_stream(1000, 1 << 20, seed=0)
    assert np.all(np.diff(ids.astype(np.int64)) > 0)
    h = zipf.empirical_entropy_bits(np.array([1, 1, 1, 1]))
    assert h == 0.0
    h2 = zipf.empirical_entropy_bits(np.arange(1024))
    assert abs(h2 - 10.0) < 1e-9
