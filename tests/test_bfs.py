"""Single-device BFS vs host oracle + Graph500 validator rules."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bfs, validate
from repro.graphgen import builder, kronecker


def _device_graph(g):
    return jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


@pytest.mark.parametrize("scale", [6, 9])
def test_bfs_levels_match_reference(scale):
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=2), n=1 << scale)
    src, dst = _device_graph(g)
    res = bfs.bfs(src, dst, jnp.int32(0), g.n)
    ref = validate.reference_bfs(g, 0)
    np.testing.assert_array_equal(np.asarray(res.level), ref)
    v = validate.validate_bfs_tree(g, np.asarray(res.parent), 0, np.asarray(res.level))
    assert v.ok, v.failures


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1 << 16), root=st.integers(0, 255))
def test_bfs_property_random_graphs(seed, root):
    """For arbitrary random graphs the BFS tree passes all 5 rules and
    levels equal the oracle's."""
    rng = np.random.default_rng(seed)
    n = 256
    m = rng.integers(1, 2048)
    edges = rng.integers(0, n, size=(m, 2))
    g = builder.build_csr(edges, n=n)
    src, dst = _device_graph(g)
    res = bfs.bfs(src, dst, jnp.int32(root), g.n)
    ref = validate.reference_bfs(g, root)
    np.testing.assert_array_equal(np.asarray(res.level), ref)
    v = validate.validate_bfs_tree(g, np.asarray(res.parent), root, np.asarray(res.level))
    assert v.ok, v.failures


def test_bfs_levels_sizes():
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=1), n=256)
    src, dst = _device_graph(g)
    res, sizes = bfs.bfs_levels(src, dst, jnp.int32(0), g.n, max_levels=16)
    sizes = np.asarray(sizes)
    n_reached = int((np.asarray(res.level) >= 0).sum())
    assert sizes.sum() + 1 == n_reached  # root not counted in level frontiers


def test_bfs_max_levels_guard():
    """Regression: an adversarial high-diameter edge list (a path) used to
    keep bfs()'s while_loop spinning for O(n) levels — the single-device
    drivers now honor the same depth cap as DistBFSConfig.max_levels."""
    n = 256
    path = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    g = builder.build_csr(path, n=n)
    src, dst = _device_graph(g)
    res = bfs.bfs(src, dst, jnp.int32(0), g.n, max_levels=10)
    assert int(res.n_levels) == 10
    level = np.asarray(res.level)
    parent = np.asarray(res.parent)
    # vertices within the cap are correct; the rest stay unreached
    np.testing.assert_array_equal(level[:11], np.arange(11))
    assert np.all(level[11:] == -1) and np.all(parent[11:] == -1)
    # same cap semantics from the scan-based driver
    res_l, sizes = bfs.bfs_levels(src, dst, jnp.int32(0), g.n, max_levels=10)
    np.testing.assert_array_equal(np.asarray(res_l.level), level)
    assert int(res_l.n_levels) == 10
    # the default cap matches the distributed driver's
    from repro.core.distributed_bfs import DistBFSConfig

    full = bfs.bfs(src, dst, jnp.int32(0), g.n, max_levels=DistBFSConfig().max_levels)
    assert int(full.n_levels) == 64 and int(np.asarray(full.level).max()) == 64


def test_validator_catches_corruption():
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=3), n=256)
    src, dst = _device_graph(g)
    res = bfs.bfs(src, dst, jnp.int32(0), g.n)
    parent = np.asarray(res.parent).copy()
    reached = np.nonzero(parent >= 0)[0]
    victim = reached[-1]
    # rule 5 violation: parent not adjacent
    bad = parent.copy()
    non_nbrs = np.setdiff1d(reached, np.append(g.neighbors(victim), victim))
    if non_nbrs.size and victim != 0:
        bad[victim] = non_nbrs[0]
        assert not validate.validate_bfs_tree(g, bad, 0).ok
    # rule 1 violation: cycle
    bad = parent.copy()
    a, b = reached[1], reached[2]
    bad[a], bad[b] = b, a
    assert not validate.validate_bfs_tree(g, bad, 0).ok
    # rule 4 violation: claim an unreached vertex
    unreached = np.nonzero(parent < 0)[0]
    if unreached.size:
        bad = parent.copy()
        bad[unreached[0]] = 0
        assert not validate.validate_bfs_tree(g, bad, 0).ok


def test_traversed_edges_teps_numerator():
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=1), n=256)
    src, dst = _device_graph(g)
    root = int(np.argmax(g.degrees()))  # Graph500 samples roots with deg > 0
    res = bfs.bfs(src, dst, jnp.int32(root), g.n)
    te = validate.traversed_edges(g, np.asarray(res.parent))
    assert 0 < te <= g.m // 2


def test_partition_2d_covers_all_edges():
    g = builder.build_csr(kronecker.kronecker_edges(8, seed=5), n=256)
    from repro.core import csr as csrmod

    bg = csrmod.partition_2d(g, rows=2, cols=2, chunk_multiple=64, e_cap_multiple=64)
    part = bg.part
    total = int(bg.e_counts.sum())
    assert total == g.m  # every symmetric edge lands in exactly one block
    # local indices decode back to the original edge multiset
    rebuilt = []
    for i in range(2):
        for j in range(2):
            sl = bg.src_local[i, j]
            dl = bg.dst_local[i, j]
            mask = sl < part.n_c
            rebuilt.append(
                np.stack([sl[mask] + j * part.n_c, dl[mask] + i * part.n_r], 1)
            )
    rebuilt = np.concatenate(rebuilt)
    orig = np.stack([g.src, g.dst], 1)
    assert np.array_equal(
        rebuilt[np.lexsort(rebuilt.T)], orig[np.lexsort(orig.T)]
    )
    # transpose permutation is a bijection
    perm = part.transpose_perm()
    assert sorted(d for _, d in perm) == list(range(4))
