"""The frontier-algebra (semiring) axis: SSSP, CC, PageRank, BFS identity.

The contract: the (message, combine, update) triple is a registry axis,
and every algebra rides the UNCHANGED wire plans and traversal policies —
``sssp`` equals host Dijkstra over the same hashed weights, ``cc`` equals
union-find min labels, ``pagerank`` converges on the global L1 residual,
and ``bfs`` through the algebra axis is bit-identical to the default
driver (the pre-refactor triple, extracted, not altered).
"""

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.comm import registry
from repro.comm.formats import INF
from repro.core import bfs, validate
from repro.core.algebra import BfsAlgebra, SsspAlgebra, edge_weight, resolve
from repro.core.centrality import tree_betweenness
from repro.graphgen import builder

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graph(n=48, m=140, seed=3):
    rng = np.random.default_rng(seed)
    g = builder.build_csr(rng.integers(0, n, size=(m, 2)), n=n)
    return g, jnp.asarray(g.src.astype(np.int32)), jnp.asarray(g.dst.astype(np.int32))


def test_algebra_registry_axis():
    """The fifth axis: registered names, instance pass-through, parameters."""
    assert set(registry.available_algebras()) >= {"bfs", "sssp", "cc", "pagerank"}
    assert resolve("sssp").name == "sssp"
    custom = SsspAlgebra(delta=7)
    assert resolve(custom) is custom  # parameterized instances skip the registry
    assert resolve("bfs").payload_is_id and not resolve("cc").payload_is_id
    assert resolve("pagerank").reduce == "sum" and resolve("sssp").reduce == "min"


def test_edge_weight_host_device_exact():
    """The uint32 avalanche hash wraps identically under numpy and jax —
    the host Dijkstra oracle prices the same weights the kernel relaxes."""
    rng = np.random.default_rng(0)
    u = rng.integers(0, 1 << 20, 256)
    v = rng.integers(0, 1 << 20, 256)
    w_np = edge_weight(u, v, xp=np)
    w_j = np.asarray(edge_weight(jnp.asarray(u), jnp.asarray(v)))
    np.testing.assert_array_equal(w_np, w_j)
    np.testing.assert_array_equal(w_np, edge_weight(v, u, xp=np))  # symmetric
    assert w_np.min() >= 1 and w_np.max() <= 31


@pytest.mark.parametrize("policy", ["top_down", "bottom_up", "direction_opt"])
def test_bfs_algebra_bit_identity(policy):
    """Regression gate for the refactor: BFS routed explicitly through the
    algebra axis is bit-identical (parent AND level planes) to the default
    driver, for every traversal policy."""
    g, src, dst = _graph()
    roots = np.array([0, 7, 33], np.int32)
    base = bfs.bfs(src, dst, roots, g.n, policy=policy)
    for algebra in ("bfs", BfsAlgebra()):
        res = bfs.bfs(src, dst, roots, g.n, policy=policy, algebra=algebra)
        np.testing.assert_array_equal(np.asarray(res.parent), np.asarray(base.parent))
        np.testing.assert_array_equal(np.asarray(res.level), np.asarray(base.level))
        assert int(res.n_levels) == int(base.n_levels)


@pytest.mark.parametrize("policy", ["top_down", "bottom_up", "direction_opt"])
def test_sssp_matches_dijkstra(policy):
    g, src, dst = _graph(seed=5)
    root = int(np.argmax(g.degrees()))
    host = validate.reference_sssp(g, root)
    res = bfs.bfs(src, dst, jnp.int32(root), g.n, policy=policy,
                  algebra="sssp", max_levels=256)
    np.testing.assert_array_equal(np.asarray(res.parent).astype(np.int64), host)
    # level records the delta-stepping round a vertex last improved in
    assert int(res.n_levels) < 256


@pytest.mark.parametrize("policy", ["top_down", "bottom_up", "direction_opt"])
def test_cc_matches_union_find(policy):
    g, src, dst = _graph(n=64, m=90, seed=9)  # sparse -> several components
    host = validate.reference_cc(g)
    assert np.unique(host).size > 1, "test graph should not be connected"
    res = bfs.bfs(src, dst, jnp.int32(0), g.n, policy=policy,
                  algebra="cc", max_levels=256)
    np.testing.assert_array_equal(np.asarray(res.parent).astype(np.int64), host)


def test_pagerank_residual_convergence():
    g, src, dst = _graph(n=64, m=300, seed=2)
    host = validate.reference_pagerank(g, n=g.n)
    res = bfs.bfs(src, dst, jnp.int32(0), g.n, algebra="pagerank",
                  max_levels=256)
    got = np.asarray(res.parent)
    # device iterates in f32, host in f64 — both stop on L1 residual 1e-4
    assert np.abs(got - host).max() < 1e-3
    assert np.abs(got.sum() - host.sum()) < 1e-2
    assert int(res.n_levels) < 256  # the residual psum terminated the loop
    # roots are irrelevant to the fixed point: a different root bit-matches
    res2 = bfs.bfs(src, dst, jnp.int32(5), g.n, algebra="pagerank",
                   max_levels=256)
    np.testing.assert_array_equal(got, np.asarray(res2.parent))


def test_tree_betweenness_path_graph():
    """Promoted centrality API: on a path 0-1-2-3, interior vertices carry
    all dependency mass (root endpoint excluded)."""
    parent = np.array([[0, 0, 1, 2]])
    level = np.array([[0, 1, 2, 3]])
    bc = tree_betweenness(parent, level, 4)
    np.testing.assert_allclose(bc, [0.0, 2.0, 1.0, 0.0])


def _run(snippet: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


_DIST_ALGEBRA_SNIPPET = """
import numpy as np, jax, jax.numpy as jnp
from repro.comm import CommStats
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder
from repro.launch import roofline
n = 1 << 10
ROWS, COLS = 2, %(cols)d
rng = np.random.default_rng(11)
g = builder.build_csr(rng.integers(0, n, size=(900, 2)), n=n)
mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS, e_cap_multiple=1024)
part = bg.part
root = int(np.argmax(g.degrees()))
host = {"sssp": validate.reference_sssp(g, root), "cc": validate.reference_cc(g)}
roots = jnp.asarray(np.array([root], np.int32))
for alg in ("sssp", "cc"):
    for mode in ("raw", "bitmap", "auto", "btfly"):
        for pol in ("top_down", "bottom_up", "direction_opt"):
            stats = CommStats()
            cfg = dbfs.DistBFSConfig(mode=mode, policy=pol, algebra=alg,
                                     max_levels=512)
            fn = dbfs.build_bfs(mesh, part, cfg, stats=stats)
            src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
            val, lev, dep = fn(src_l, dst_l, roots)
            got = np.asarray(val)[0][:n].astype(np.int64)
            np.testing.assert_array_equal(
                got, host[alg], err_msg=f"{alg}/{mode}/{pol}")
            if pol == "direction_opt":
                # ledger <-> HLO reconciliation rides the same build
                compiled = jax.jit(fn).lower(
                    src_l, dst_l, jax.ShapeDtypeStruct((1,), jnp.int32)
                ).compile()
                cmp = roofline.compare_comm_stats(stats, compiled.as_text())
                assert cmp.match, (alg, mode, pol, cmp.diff())
print("DIST ALGEBRA OK")
"""


@pytest.mark.slow
def test_sssp_cc_all_plans_4dev():
    """Tentpole acceptance on the C=2 grid: SSSP == host Dijkstra and
    CC == union-find for all 4 wire plans x 3 policies, with the
    CommStats/HLO reconciliation checked on the adaptive policy."""
    out = _run(_DIST_ALGEBRA_SNIPPET % {"cols": 2}, devices=4)
    assert "DIST ALGEBRA OK" in out


@pytest.mark.slow
def test_sssp_cc_all_plans_c3_6dev():
    """Same property on the C=3 grid: value payloads ride the butterfly
    fold/unfold stages and the non-power-of-two alltoall geometry."""
    out = _run(_DIST_ALGEBRA_SNIPPET % {"cols": 3}, devices=6)
    assert "DIST ALGEBRA OK" in out


_DIST_PAGERANK_SNIPPET = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder
n = 1 << 10
rng = np.random.default_rng(4)
g = builder.build_csr(rng.integers(0, n, size=(2000, 2)), n=n)
mesh = jax.make_mesh((2, 2), ("data", "model"))
bg = csrmod.partition_2d(g, rows=2, cols=2, e_cap_multiple=4096)
part = bg.part
host = validate.reference_pagerank(g, n=part.n)
cfg = dbfs.DistBFSConfig(mode="auto", policy="top_down", algebra="pagerank",
                         max_levels=256)
fn = dbfs.build_bfs(mesh, part, cfg)
src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
val, lev, dep = fn(src_l, dst_l, jnp.asarray(np.array([3], np.int32)))
got = np.asarray(val)[0]
assert int(dep) < 256
assert np.abs(got - host).max() < 1e-3, np.abs(got - host).max()
print("DIST PAGERANK OK")
"""


@pytest.mark.slow
def test_pagerank_distributed_4dev():
    """The plus-times algebra end-to-end: the f32-bitcast mass planes ride
    the dense combine wire and the residual psum terminates the loop at
    the same fixed point as host power iteration (padded-n convention)."""
    out = _run(_DIST_PAGERANK_SNIPPET, devices=4)
    assert "DIST PAGERANK OK" in out
