"""Minimal deterministic stand-in for Hypothesis.

Loaded by the root conftest.py ONLY when the real ``hypothesis`` package is
unavailable (see pyproject.toml's test extra for the real dependency).
Covers exactly the API surface this repo's tests use:

    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(lo, hi), y=st.floats(lo, hi))

``given`` degrades the property test to ``max_examples`` seeded-random
samples per strategy, always including the boundary values first.  No
shrinking, no database — but every property still runs against the
boundaries plus a deterministic random sweep.
"""

from __future__ import annotations

import functools
import inspect
import random

from hypothesis import strategies  # noqa: F401  (re-export: `from hypothesis import strategies as st`)

__version__ = "0.0.0-shim"
_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator recording max_examples; composes with ``given`` either side."""

    def deco(f):
        f._hyp_max_examples = max_examples
        return f

    return deco


def given(*arg_strats, **kw_strats):
    if arg_strats:
        raise TypeError("shim supports keyword strategies only (as this repo uses)")

    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f.__qualname__)  # deterministic per test
            for i in range(n):
                drawn = {k: s.draw(rng, i) for k, s in kw_strats.items()}
                f(*args, **{**kwargs, **drawn})

        # pytest must not see the strategy-filled params as fixtures
        sig = inspect.signature(f)
        remaining = [p for name, p in sig.parameters.items() if name not in kw_strats]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.__dict__.pop("__wrapped__", None)
        return wrapper

    return deco
