"""Strategy objects for the Hypothesis shim (boundaries first, then seeded
random draws).  Only the strategies this repo's tests use."""

from __future__ import annotations

import dataclasses
import random


@dataclasses.dataclass(frozen=True)
class _Integers:
    min_value: int
    max_value: int

    def draw(self, rng: random.Random, i: int) -> int:
        if i == 0:
            return self.min_value
        if i == 1:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


@dataclasses.dataclass(frozen=True)
class _Floats:
    min_value: float
    max_value: float

    def draw(self, rng: random.Random, i: int) -> float:
        if i == 0:
            return self.min_value
        if i == 1:
            return self.max_value
        return rng.uniform(self.min_value, self.max_value)


@dataclasses.dataclass(frozen=True)
class _SampledFrom:
    elements: tuple

    def draw(self, rng: random.Random, i: int):
        if i < len(self.elements):
            return self.elements[i]
        return rng.choice(self.elements)


def sampled_from(elements) -> _SampledFrom:
    return _SampledFrom(tuple(elements))


def integers(min_value: int, max_value: int) -> _Integers:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **_kw) -> _Floats:
    return _Floats(min_value, max_value)


def booleans() -> _SampledFrom:
    return _SampledFrom((False, True))
