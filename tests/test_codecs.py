"""Host codec correctness: roundtrips, ratios, property tests (paper §5)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import codecs, registry, threshold
from repro.graphgen import zipf

ALL_CODECS = registry.available_codecs()


@pytest.mark.parametrize("name", ALL_CODECS)
def test_roundtrip_sorted_stream(name):
    ids = zipf.sorted_id_stream(4096, 1 << 20, seed=1)
    c = registry.make_codec(name)
    blob = c.encode(ids)
    out = c.decode(blob, ids.size)
    np.testing.assert_array_equal(out, ids)


@pytest.mark.parametrize("name", [n for n in ALL_CODECS if n != "bitmap"])
def test_roundtrip_unsorted(name):
    c = registry.make_codec(name)
    if c.is_sorted_input:
        pytest.skip("delta codec requires sorted input")
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 30, size=1000, dtype=np.uint32)
    np.testing.assert_array_equal(c.decode(c.encode(vals), vals.size), vals)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    universe=st.integers(2000, 1 << 24),
    seed=st.integers(0, 1 << 16),
)
def test_bp128d_roundtrip_property(n, universe, seed):
    """The paper's codec is lossless for any sorted unique id stream."""
    rng = np.random.default_rng(seed)
    ids = np.unique(rng.integers(0, universe, size=n, dtype=np.uint32))
    c = codecs.BP128(delta=True)
    np.testing.assert_array_equal(c.decode(c.encode(ids), ids.size), ids)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 1500),
    seed=st.integers(0, 1 << 16),
    spike=st.integers(0, 1 << 31),
)
def test_pfor_exceptions_property(n, seed, spike):
    """Patched coding survives adversarial outliers (paper §5.2 exceptions)."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 256, size=n, dtype=np.uint32)
    vals[rng.integers(0, n)] = spike  # one huge exception
    c = codecs.PFOR(delta=False)
    np.testing.assert_array_equal(c.decode(c.encode(vals), n), vals)


def test_delta_ratio_beats_raw_on_frontier_data():
    """Paper Table 5.4: delta+bitpack compresses sorted small-gap streams
    far below 32 bits/int; ratio must beat the no-delta variant."""
    ids = zipf.sorted_id_stream(20000, 1 << 21, seed=3)
    r_delta = codecs.BP128(delta=True).ratio(ids)
    r_plain = codecs.BP128(delta=False).ratio(ids)
    assert r_delta > 2.0
    assert r_delta > r_plain


def test_pack_bits_all_widths():
    rng = np.random.default_rng(0)
    for b in range(1, 33):
        hi = np.uint64(1) << b
        vals = (rng.integers(0, int(hi), size=517, dtype=np.uint64)).astype(np.uint32)
        words = codecs.pack_bits(vals, b)
        out = codecs.unpack_bits(words, b, vals.size)
        np.testing.assert_array_equal(out, vals)


def test_empirical_entropy_matches_paper_band():
    """Paper §5.4.1: frontier gap streams have ~15-bit empirical entropy and
    compress to near-entropy size."""
    ids = zipf.sorted_id_stream(29899, 1 << 16, seed=0)
    gaps = codecs.delta_encode(ids)
    h = zipf.empirical_entropy_bits(gaps)
    blob = codecs.BP128(delta=True).encode(ids)
    bits_per_int = len(blob) * 8 / ids.size
    assert bits_per_int < 32
    assert bits_per_int < h + 8  # within a word of entropy + headers


def test_threshold_policy():
    pol = threshold.ThresholdPolicy(min_ints=1024)
    assert not pol.should_compress(100, ratio=8.0)  # below min size
    assert pol.should_compress(1 << 20, ratio=8.0)  # ICI link, TPU codec
    # same-host fast path: compression not worth it (paper §9 idea)
    assert not pol.should_compress(1 << 20, ratio=2.0, same_host=True)
    # the paper's own environment: CPU SIMD codec + GigE -> big wins
    creek = threshold.ThresholdPolicy.paper_creek()
    assert creek.modeled_speedup(1 << 20, ratio=8.0) > 4.0
    # a CPU-speed codec on a TPU-speed link would NOT pay — the reason the
    # bitpack kernel lives on-device (DESIGN.md §3)
    cpu_on_ici = threshold.ThresholdPolicy(codec_speed_mips=3200, codec_dspeed_mips=4700)
    assert cpu_on_ici.modeled_speedup(1 << 20, ratio=8.0) < 1.5


def test_compression_shim_retired():
    """Satellite: the ``repro.compression`` deprecation shim is gone — the
    old package name no longer resolves, and the absorbed homes answer."""
    import importlib

    with pytest.raises(ImportError):
        importlib.import_module("repro.compression")
    assert registry.make_codec("bp128d").name == "bp128d"
    assert codecs is not None and threshold is not None
