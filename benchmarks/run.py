"""Run every benchmark at reduced size; one CSV block per paper table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip scaling]

Also writes ``BENCH_comm.json`` (per-zone / per-format communication bytes
from the CommStats host replay) so successive PRs have a machine-readable
perf trajectory to compare against.
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def write_bench_comm(
    path: str,
    full: bool,
    table: list[dict] | None = None,
    policy_levels: dict | None = None,
    batch: dict | None = None,
    compute: dict | None = None,
    teps: dict | None = None,
) -> None:
    from benchmarks import bfs_comm, breakdown

    from repro.core import csr as csrmod

    scale, rows, cols = _bench_comm_size(full)
    # the padding rule partition_2d applies (1024-multiple chunks): the
    # staged-byte-model check recomputes wire geometry from (n, chunk)
    n, chunk = csrmod.padded_geometry(1 << scale, rows, cols)
    prebuilt = None
    if table is None or batch is None:
        # one graph + hub reference for both replay suites
        prebuilt = bfs_comm.build_replay_graph(scale, rows, cols)
    if table is None:
        table, policy_levels = bfs_comm.run(
            scale=scale, rows=rows, cols=cols, prebuilt=prebuilt
        )
    if batch is None:
        batch = bfs_comm.run_batch(
            scale=scale, rows=rows, cols=cols, prebuilt=prebuilt
        )
    if compute is None:
        compute = breakdown.expansion_breakdown(scale=scale, rows=rows, cols=cols)
    # the multi-source rows ride the same table (batch column + per-source
    # bytes); single-source rows carry batch=1 for uniform consumers
    for r in table:
        r.setdefault("batch", 1)
    for policy, entry in batch["policies"].items():
        for plan, d in entry["plans"].items():
            table.append(
                {
                    "policy": policy,
                    "zone": "total",
                    "format": "packed",
                    "plan": plan,
                    "batch": d["batch"],
                    "bytes": d["total_bytes"],
                    "bytes_per_source": d["bytes_per_source"],
                    "b1_total_bytes": d["b1_total_bytes"],
                }
            )
    doc = {
        "benchmark": "bfs_comm",
        "scale": scale,
        "rows": rows,
        "cols": cols,
        "chunk": chunk,  # the staged byte model needs s and n
        "n": n,
        "policies": list(bfs_comm.POLICIES),
        "plans": list(bfs_comm.PLANS),
        "table": table,
        # per-policy per-level direction + packed row bytes: makes the
        # direction-opt vs top_down wire saving visible level by level
        "policy_levels": policy_levels or {},
        # multi-source batch section: B=4 planes vs the B=1 replay of the
        # same packed-wire model (shared headers + consensus amortization)
        "batch": batch,
        # local-expansion compute breakdown: per-level push/pull wall time
        # per backend on the hub graph (the axis the byte tables can't see)
        "compute": compute,
        # Graph500 Kernel-2 throughput: harmonic-mean TEPS over the spec's
        # valid-root sample (benchmarks.teps), the trajectory's speed row
        "teps": teps or {},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {path} ({len(table)} rows)")


def _bench_comm_size(full: bool) -> tuple[int, int, int]:
    return (17, 4, 4) if full else (15, 2, 2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-closer sizes (slow)")
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument(
        "--bench-json",
        default=os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                             "BENCH_comm.json"),
        help="where to write the BENCH_comm.json trajectory artifact",
    )
    args = ap.parse_args()

    from benchmarks import bfs_comm, breakdown, codecs, frontier_stats, teps

    bench_table: list[tuple] = []  # shared with write_bench_comm below
    compute_box: list[dict] = []  # expansion breakdown, shared the same way
    teps_box: list[dict] = []  # harmonic-TEPS row, shared the same way

    def teps_suite() -> None:
        teps_box.append(teps.main())

    def breakdown_suite() -> None:
        breakdown.main_zones()
        scale, rows, cols = _bench_comm_size(args.full)
        compute = breakdown.expansion_breakdown(scale=scale, rows=rows, cols=cols)
        breakdown.print_expansion(compute)
        compute_box.append(compute)

    def bfs_comm_suite() -> None:
        scale, rows, cols = _bench_comm_size(args.full)
        # one graph + hub reference for both replay suites
        prebuilt = bfs_comm.build_replay_graph(scale, rows, cols)
        table, policy_levels = bfs_comm.run(
            scale=scale, rows=rows, cols=cols, prebuilt=prebuilt
        )
        bfs_comm.print_table(table)
        bfs_comm.print_levels(policy_levels)
        batch = bfs_comm.run_batch(
            scale=scale, rows=rows, cols=cols, prebuilt=prebuilt
        )
        bfs_comm.print_batch(batch)
        bench_table.append((table, policy_levels, batch))

    suites = [
        ("codecs (Tables 5.4/5.5)", codecs.main),
        ("frontier_stats (Fig 5.2 / Table 5.3)", frontier_stats.main),
        ("bfs_comm (Tables 7.4/7.5)", bfs_comm_suite),
        ("breakdown (Fig 7.3 + expansion backends)", breakdown_suite),
        ("teps (§2.6.3)", teps_suite),
    ]
    if args.full and "scaling" not in args.skip:
        from benchmarks import scaling

        suites.append(("scaling (Fig 7.1/7.2)", scaling.main))

    failures = []
    for name, fn in suites:
        key = name.split(" ")[0]
        if key in args.skip:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"# done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    # the artifact reuses the suite's table; a skipped or failed bfs_comm
    # must not be silently re-run here
    if "bench-json" not in args.skip and bench_table:
        try:
            table, policy_levels, batch = bench_table[0]
            write_bench_comm(
                args.bench_json, args.full, table=table,
                policy_levels=policy_levels, batch=batch,
                compute=compute_box[0] if compute_box else None,
                teps=teps_box[0] if teps_box else None,
            )
        except Exception:  # noqa: BLE001
            failures.append("bench-json")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
