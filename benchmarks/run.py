"""Run every benchmark at reduced size; one CSV block per paper table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip scaling]
"""

from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-closer sizes (slow)")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args()

    from benchmarks import bfs_comm, breakdown, codecs, frontier_stats, teps

    suites = [
        ("codecs (Tables 5.4/5.5)", codecs.main),
        ("frontier_stats (Fig 5.2 / Table 5.3)", frontier_stats.main),
        ("bfs_comm (Tables 7.4/7.5)", bfs_comm.main),
        ("breakdown (Fig 7.3)", breakdown.main),
        ("teps (§2.6.3)", teps.main),
    ]
    if args.full and "scaling" not in args.skip:
        from benchmarks import scaling

        suites.append(("scaling (Fig 7.1/7.2)", scaling.main))

    failures = []
    for name, fn in suites:
        key = name.split(" ")[0]
        if key in args.skip:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"# done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
