"""Paper §2.6.3: the Graph500 TEPS harness.

Runs the benchmark's Algorithm 1 at reduced scale: untimed generation,
timed Kernel 1 (CSR construction), N timed BFS iterations from random
roots with validation, TEPS reported as the harmonic mean (the spec's
statistic).  64 roots at full scale; reduced here for CPU wall-clock.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import bfs as bfsmod
from repro.core import validate
from repro.graphgen import builder, kronecker


def run(scale: int = 13, n_roots: int = 8, seed: int = 1, validate_trees: bool = True):
    import jax
    import jax.numpy as jnp

    edges = kronecker.kronecker_edges(scale, seed=seed)
    t0 = time.perf_counter()
    g = builder.build_csr(edges, n=1 << scale)
    kernel1_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    deg = g.degrees()
    roots = rng.choice(np.nonzero(deg > 0)[0], size=n_roots, replace=False)
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    # warm-up compile (untimed, like the spec's untimed setup)
    jax.block_until_ready(bfsmod.bfs(src, dst, jnp.int32(int(roots[0])), g.n).parent)

    teps_list, times = [], []
    for root in roots:
        t0 = time.perf_counter()
        res = bfsmod.bfs(src, dst, jnp.int32(int(root)), g.n)
        jax.block_until_ready(res.parent)
        dt = time.perf_counter() - t0
        te = validate.traversed_edges(g, np.asarray(res.parent))
        if validate_trees:
            v = validate.validate_bfs_tree(g, np.asarray(res.parent), int(root),
                                           np.asarray(res.level))
            assert v.ok, v.failures
        teps_list.append(te / dt)
        times.append(dt)
    harmonic = len(teps_list) / sum(1.0 / t for t in teps_list)
    return {
        "scale": scale,
        "n": g.n,
        "m_input": g.m_input,
        "kernel1_s": kernel1_s,
        "n_roots": n_roots,
        "teps_harmonic_mean": harmonic,
        "mean_time_s": float(np.mean(times)),
        "validated": validate_trees,
    }


def main() -> None:
    r = run()
    print("scale,n,m_input,kernel1_s,n_roots,TEPS_harmonic,mean_time_s,validated")
    print(f"{r['scale']},{r['n']},{r['m_input']},{r['kernel1_s']:.3f},"
          f"{r['n_roots']},{r['teps_harmonic_mean']:.3e},{r['mean_time_s']:.4f},"
          f"{r['validated']}")


if __name__ == "__main__":
    main()
