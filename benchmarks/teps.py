"""Paper §2.6.3: the Graph500 TEPS harness.

Runs the benchmark's Algorithm 1 at reduced scale: untimed generation,
timed Kernel 1 (CSR construction), N timed BFS iterations from random
roots with validation, TEPS reported as the harmonic mean (the spec's
statistic).  64 roots at full scale; reduced here for CPU wall-clock.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import bfs as bfsmod
from repro.core import validate
from repro.graphgen import builder, kronecker


def valid_roots(g, n_roots: int, seed: int = 1) -> np.ndarray:
    """Graph500 search keys: sampled uniformly, WITHOUT replacement, from
    vertices with at least one edge (the spec's validity condition — an
    isolated root would trivially 'traverse' zero edges)."""
    rng = np.random.default_rng(seed)
    cand = np.nonzero(g.degrees() > 0)[0]
    if cand.size < n_roots:
        raise ValueError(
            f"graph has only {cand.size} non-isolated vertices; "
            f"cannot draw {n_roots} distinct valid roots"
        )
    return rng.choice(cand, size=n_roots, replace=False).astype(np.int32)


def harmonic_mean(xs) -> float:
    """The spec's TEPS statistic (insensitive to a few fast outliers)."""
    return len(xs) / sum(1.0 / x for x in xs)


def run(scale: int = 13, n_roots: int = 8, seed: int = 1, validate_trees: bool = True):
    import jax
    import jax.numpy as jnp

    edges = kronecker.kronecker_edges(scale, seed=seed)
    t0 = time.perf_counter()
    g = builder.build_csr(edges, n=1 << scale)
    kernel1_s = time.perf_counter() - t0

    roots = valid_roots(g, n_roots, seed=seed)
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    # warm-up compile (untimed, like the spec's untimed setup)
    jax.block_until_ready(bfsmod.bfs(src, dst, jnp.int32(int(roots[0])), g.n).parent)

    teps_list, times = [], []
    for root in roots:
        t0 = time.perf_counter()
        res = bfsmod.bfs(src, dst, jnp.int32(int(root)), g.n)
        jax.block_until_ready(res.parent)
        dt = time.perf_counter() - t0
        te = validate.traversed_edges(g, np.asarray(res.parent))
        if validate_trees:
            v = validate.validate_bfs_tree(g, np.asarray(res.parent), int(root),
                                           np.asarray(res.level))
            assert v.ok, v.failures
        teps_list.append(te / dt)
        times.append(dt)
    harmonic = harmonic_mean(teps_list)
    return {
        "scale": scale,
        "n": g.n,
        "m_input": g.m_input,
        "kernel1_s": kernel1_s,
        "n_roots": n_roots,
        "teps_harmonic_mean": harmonic,
        "mean_time_s": float(np.mean(times)),
        "validated": validate_trees,
    }


def main() -> dict:
    r = run()
    print("scale,n,m_input,kernel1_s,n_roots,TEPS_harmonic,mean_time_s,validated")
    print(f"{r['scale']},{r['n']},{r['m_input']},{r['kernel1_s']:.3f},"
          f"{r['n_roots']},{r['teps_harmonic_mean']:.3e},{r['mean_time_s']:.4f},"
          f"{r['validated']}")
    return r


if __name__ == "__main__":
    main()
