"""Paper Tables 7.4/7.5: per-zone communication volume before/after
compression, and modeled communication-time reduction.

Replays a real multi-rank BFS level by level on the host (numpy),
accumulating the exact bytes each zone would move under each wire format
through :class:`repro.comm.CommStats` — the byte arithmetic lives in the
wire formats (:mod:`repro.comm.formats`), not in this benchmark:

  zones: vertexBroadcast / columnCommunication / rowCommunication /
         predecessorReduction  (the paper's instrumented regions, §4.2.1)

  formats: raw 32-bit ids (Baseline), dense bitmap, bucketed PFOR16 packed
           (the in-graph static-shape codec), and the variable-length
           BP128+delta host codec (the paper's S4-BP128).

Time reduction (Table 7.5 analog) uses the threshold-policy link model —
compress+transmit+decompress at measured codec speeds vs plain transmit.
"""

from __future__ import annotations

import numpy as np

from repro.comm import BitmapFormat, CommStats, DenseFormat, RawIdFormat
from repro.comm.ladder import BucketLadder
from repro.compression import codecs, threshold
from repro.core import csr as csrmod
from repro.core import validate
from repro.graphgen import builder, kronecker

ZONES = (
    "vertexBroadcast",
    "columnCommunication",
    "rowCommunication",
    "predecessorReduction",
)
FORMATS = ("raw", "bitmap", "packed", "bp128d")


def _packed_wire_bytes(ladder: BucketLadder, ids: np.ndarray) -> int:
    """Wire bytes of one packed stream under the ladder's bucket choice."""
    count = ids.size
    exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if count else 0
    b = int(ladder.bucket_for(np.int32(count), np.int32(exc)))
    if b < len(ladder.specs):
        return ladder.formats()[b].wire_bytes
    return 4 * ladder.floor_words


def simulate_zones(scale: int = 17, rows: int = 4, cols: int = 4, seed: int = 1):
    """Host replay of the 2D BFS communication; returns a filled CommStats
    whose phases are the paper's zones and fmts the four wire formats."""
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    part = bg.part
    s = part.chunk
    wp = 16 if part.n_c <= (1 << 16) else 32
    ladder = BucketLadder.default(s)  # column (membership vs 1-bit floor)
    row_ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)

    stats = CommStats()
    raw_col = RawIdFormat(s)
    bitmap = BitmapFormat(s)
    dense = DenseFormat(s)
    bp = codecs.BP128(delta=True)
    for fmt in FORMATS:  # root broadcast: 8 bytes to every rank, any format
        stats.add("vertexBroadcast", fmt, "all-gather", 8 * rows * cols)
    max_level = int(level.max())
    owner = np.minimum(np.arange(part.n) // s, rows * cols - 1)

    for lv in range(max_level):
        frontier = np.nonzero(level == lv)[0]
        # --- column phase: each owner rank all-gathers its chunk's frontier
        # to the R-1 other ranks in its grid column
        for q in range(rows * cols):
            ids = frontier[owner[frontier] == q] - q * s
            n_recv = rows - 1
            stats.add("columnCommunication", "raw", "all-gather",
                      raw_col.wire_bytes * n_recv)
            stats.add("columnCommunication", "bitmap", "all-gather",
                      bitmap.wire_bytes * n_recv)
            stats.add("columnCommunication", "packed", "all-gather",
                      _packed_wire_bytes(ladder, ids) * n_recv)
            blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
            stats.add("columnCommunication", "bp128d", "all-gather",
                      len(blob) * n_recv)
        # --- row phase: candidate (id, parent) subchunks to owners
        nxt = np.nonzero(level == lv + 1)[0]
        for q in range(rows * cols):
            ids = nxt[owner[nxt] == q] - q * s
            n_senders = cols - 1
            stats.add("rowCommunication", "raw", "all-to-all",
                      dense.wire_bytes * n_senders)  # dense int32 candidates
            stats.add("rowCommunication", "bitmap", "all-to-all",
                      dense.wire_bytes * n_senders)  # parents stay dense
            stats.add("rowCommunication", "packed", "all-to-all",
                      _packed_wire_bytes(row_ladder, ids) * n_senders)
            blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
            stats.add("rowCommunication", "bp128d", "all-to-all",
                      (len(blob) + 2 * ids.size) * n_senders)

    # predecessor reduction: one dense pass at the end (uncompressed in the
    # paper too — its Table 7.4 shows 0% there)
    for fmt in FORMATS:
        stats.add("predecessorReduction", fmt, "all-gather", 4 * part.n)
    return stats, g, part


def run(scale: int = 17, rows: int = 4, cols: int = 4):
    stats, g, part = simulate_zones(scale, rows, cols)
    zones = stats.per_phase_fmt()
    pol = threshold.ThresholdPolicy()
    table = []
    for zone in ZONES:
        fmts = zones[zone]
        raw = fmts["raw"]
        for fmt in FORMATS:
            b = fmts[fmt]
            red = 100.0 * (1 - b / raw) if raw else 0.0
            speedup = pol.modeled_speedup(max(raw / 4, 1), ratio=max(raw / max(b, 1), 1.0))
            table.append(
                {
                    "zone": zone,
                    "format": fmt,
                    "bytes": b,
                    "reduction_pct": red,
                    "modeled_time_reduction_pct": 100.0 * (1 - 1 / speedup)
                    if fmt != "raw"
                    else 0.0,
                }
            )
    return table


def print_table(table: list[dict]) -> None:
    print("zone,format,bytes,data_reduction_pct,modeled_time_reduction_pct")
    for r in table:
        print(f"{r['zone']},{r['format']},{r['bytes']},{r['reduction_pct']:.2f},"
              f"{r['modeled_time_reduction_pct']:.2f}")


def main() -> None:
    print_table(run())


if __name__ == "__main__":
    main()
