"""Paper Tables 7.4/7.5: per-zone communication volume before/after
compression, and modeled communication-time reduction — now with a
*policy* dimension (direction-optimizing traversal, paper §3.1).

Replays a real multi-rank BFS level by level on the host (numpy),
accumulating the exact bytes each zone would move under each wire format
AND each traversal policy through :class:`repro.comm.CommStats` — the byte
arithmetic lives in the wire formats (:mod:`repro.comm.formats`), not in
this benchmark:

  zones: vertexBroadcast / columnCommunication / rowCommunication /
         predecessorReduction  (the paper's instrumented regions, §4.2.1)

  formats: raw 32-bit ids (Baseline), dense bitmap, bucketed PFOR16 packed
           (the in-graph static-shape codec), and the variable-length
           BP128+delta host codec (the paper's S4-BP128).

  policies: top_down (push ALLTOALLV row phase), bottom_up (pull:
            found-bitmap + bit-packed parents, plus the unreached-bitmap
            all-gather folded into rowCommunication), direction_opt
            (per-level switch on the shared density oracle — the same
            alpha the device driver derives from the bucket ladder).

  plans: alltoall (the direct row exchange) vs btfly (ButterFly BFS:
         log2(C) staged ppermute rounds whose merged stream is re-bucketed
         per hop — the replay mirrors the device stage schedule, logs each
         stage's consensus format, and its bytes must reconcile with the
         static byte model; scripts/check_bench_comm.py enforces that).

The row phase buckets each (sender column, destination chunk) stream
separately and takes the max over the grid row — the device's pmax
consensus — NOT the union stream per owner chunk, which underestimates
both the counts and the consensus escalation.

Time reduction (Table 7.5 analog) uses the threshold-policy link model —
compress+transmit+decompress at measured codec speeds vs plain transmit.
"""

from __future__ import annotations

import numpy as np

from repro.comm import BitmapFormat, BitmapParentFormat, CommStats, DenseFormat, RawIdFormat
from repro.comm import butterfly
from repro.comm.ladder import BucketLadder
from repro.compression import codecs, threshold
from repro.core import csr as csrmod
from repro.core import traversal, validate
from repro.core.distributed_bfs import parent_width_class
from repro.graphgen import builder, kronecker

ZONES = (
    "vertexBroadcast",
    "columnCommunication",
    "rowCommunication",
    "predecessorReduction",
)
FORMATS = ("raw", "bitmap", "packed", "bp128d")
#: exchange plans of the row phase: the direct ALLTOALLV and the staged
#: butterfly (log2(C) ppermute rounds, merged stream re-bucketed per hop)
PLANS = ("alltoall", "btfly")
POLICIES = traversal.POLICIES


def _host_bucket(ladder: BucketLadder, ids: np.ndarray) -> int:
    """The ladder's bucket for one sorted id stream (host mirror of
    ``BucketLadder.bucket_for`` — smallest spec whose id and exception
    capacities both fit)."""
    count = ids.size
    exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if count else 0
    for i, spec in enumerate(ladder.specs):
        if count <= spec.cap and exc <= spec.exc_cap:
            return i
    return len(ladder.specs)


def _bucket_wire(ladder: BucketLadder, bucket: int, floor_fmt=None):
    """(format name, wire bytes) of one subchunk at ``bucket``."""
    if bucket < len(ladder.specs):
        fmt = ladder.formats()[bucket]
        return fmt.name, fmt.wire_bytes
    if floor_fmt is not None:
        return floor_fmt.name, floor_fmt.wire_bytes
    return "bitmap", 4 * ladder.floor_words


def _packed_wire_bytes(ladder: BucketLadder, ids: np.ndarray) -> int:
    """Wire bytes of one packed stream under the ladder's bucket choice."""
    return _bucket_wire(ladder, _host_bucket(ladder, ids))[1]


def _btfly_row_stage_replay(streams, cols: int, ladder: BucketLadder,
                            floor_fmt):
    """Host replay of the butterfly row phase over ONE grid row.

    ``streams[(j, k)]``: sorted local candidate ids sender column ``j``
    holds for the row's ``k``-th destination chunk.  Mirrors the device
    schedule exactly — fold, log2(P) pairwise stages, unfold — including the
    per-stage row-wide format consensus (max bucket over every subchunk on
    the wire that stage) and the union-merge that the next stage re-buckets.
    Returns (total bytes, stage log)."""
    sched = butterfly.ButterflySchedule(cols)
    p, extra, slots = sched.p, sched.extra, sched.slots
    empty = np.empty(0, np.int64)

    def leaf_streams(j):
        rows_ = {}
        for r in range(p):
            rows_[(r, 0)] = streams.get((j, r), empty)
            if slots == 2:
                rows_[(r, 1)] = streams.get((j, p + r), empty) if r < extra else empty
        return rows_

    state = {j: leaf_streams(j) for j in range(cols)}
    total = 0
    log = []

    def do_exchange(label, sends):
        """sends: list of (src, dst, [leaf keys]) — consensus + merge."""
        nonlocal total
        blocks = {src: [state[src][key] for key in keys] for src, dst, keys in sends}
        bucket = max(
            (_host_bucket(ladder, ids) for blk in blocks.values() for ids in blk),
            default=0,
        )
        fmt, unit = _bucket_wire(ladder, bucket, floor_fmt)
        n_sub = len(sends[0][2])
        assert all(len(keys) == n_sub for _, _, keys in sends)
        nbytes = len(sends) * n_sub * unit
        total += nbytes
        log.append({"stage": label, "fmt": fmt, "senders": len(sends),
                    "subchunks": n_sub, "bytes": nbytes})
        merged = {}
        for src, dst, keys in sends:
            for key in keys:
                merged.setdefault(dst, {})[key] = np.union1d(
                    state[dst][key], state[src][key]
                )
        for dst, upd in merged.items():
            state[dst].update(upd)

    all_leaves = [(r, sl) for r in range(p) for sl in range(slots)]
    if extra:
        do_exchange(
            "fold", [(p + e, e, all_leaves) for e in range(extra)]
        )
    for t in range(sched.n_stages):
        m = 1 << t
        sends = []
        for j in range(p):
            send_rows = [((j ^ m) & (2 * m - 1)) + 2 * m * i
                         for i in range(sched.stage_blocks(t))]
            keys = [(r, sl) for r in send_rows for sl in range(slots)]
            sends.append((j, j ^ m, keys))
        do_exchange(str(t), sends)
    if extra:
        do_exchange(
            "unfold", [(e, p + e, [(e, 1)]) for e in range(extra)]
        )
    return total, log


def _btfly_unreached_stage_replay(chunk_ids, s: int, cols: int,
                                  ladder: BucketLadder):
    """Host replay of the staged unreached all-gather over one grid row.

    ``chunk_ids[k]``: sorted local unreached ids of the row's ``k``-th
    chunk.  The doubling block keeps chunk identity, so per-subchunk
    buckets never change — only the block size per stage does."""
    sched = butterfly.ButterflySchedule(cols)
    p, extra, slots = sched.p, sched.extra, sched.slots
    bitmap = BitmapFormat(s)
    empty = np.empty(0, np.int64)

    def leaf_ids(r, sl):
        q = r if sl == 0 else p + r
        return chunk_ids[q] if (sl == 0 or r < extra) else empty

    total = 0
    log = []

    def do_exchange(label, n_senders, leaf_sets):
        nonlocal total
        bucket = max(
            (_host_bucket(ladder, leaf_ids(r, sl)) for leaves in leaf_sets
             for r, sl in leaves),
            default=0,
        )
        fmt, unit = _bucket_wire(ladder, bucket, bitmap)
        n_sub = len(leaf_sets[0])
        nbytes = n_senders * n_sub * unit
        total += nbytes
        log.append({"stage": label, "fmt": fmt, "senders": n_senders,
                    "subchunks": n_sub, "bytes": nbytes})

    if extra:
        do_exchange("fold", extra, [[(e, 1)] for e in range(extra)])
    for t in range(sched.n_stages):
        blk = 1 << t
        sets = []
        for j in range(p):
            start = (j >> t) << t
            sets.append([(start + i, sl) for i in range(blk)
                         for sl in range(slots)])
        do_exchange(str(t), p, sets)
    if extra:
        all_leaves = [(r, sl) for r in range(p) for sl in range(slots)]
        do_exchange("unfold", extra, [all_leaves for _ in range(extra)])
    return total, log


def build_replay_graph(scale: int, rows: int, cols: int, seed: int = 1):
    """Graph + partition + reference levels, shared across policy replays
    (the dominant cost — built once, not once per policy)."""
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    return g, bg.part, level


def simulate_zones(
    scale: int = 17, rows: int = 4, cols: int = 4, seed: int = 1,
    policy: str = "top_down", prebuilt=None,
):
    """Host replay of the 2D BFS communication under one traversal policy.

    Returns (stats, g, part, directions): a filled CommStats whose phases
    are the paper's zones and fmts the four wire formats, plus the
    per-level direction/byte log that makes the policy dimension visible
    in BENCH_comm.json.  ``prebuilt`` (from :func:`build_replay_graph`)
    skips the graph/reference rebuild."""
    g, part, level = prebuilt or build_replay_graph(scale, rows, cols, seed)
    s = part.chunk
    wp = parent_width_class(part.n_c)
    ladder = BucketLadder.default(s)  # column (membership vs 1-bit floor)
    row_ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    # the butterfly's row wire: global-parent payload class + its dense
    # floor (found-bitmap + packed parents) — the same geometry the device
    # plan builds, so stage formats reconcile with the static byte model
    bt_ladder, bt_floor = butterfly.row_wire(s, part.n)
    # the SAME oracle the device driver uses: direction flips where the row
    # ladder's sparse capacities run out
    oracle = traversal.DensityOracle(part.n, alpha=traversal.ladder_alpha(s, wp))

    stats = CommStats()
    raw_col = RawIdFormat(s)
    bitmap = BitmapFormat(s)
    dense = DenseFormat(s)
    bmp_parent = BitmapParentFormat(s, wp) if wp < 32 else None
    bp = codecs.BP128(delta=True)
    for fmt in FORMATS:  # root broadcast: 8 bytes to every rank, any format
        stats.add("vertexBroadcast", fmt, "all-gather", 8 * rows * cols)
    max_level = int(level.max())
    owner = np.minimum(np.arange(part.n) // s, rows * cols - 1)
    level_pad = np.full(part.n, -1, level.dtype)
    level_pad[: g.n] = level

    use_bu = policy == "bottom_up"  # host mirror of the carry's use_bu flag
    directions = []
    for lv in range(max_level):
        frontier = np.nonzero(level == lv)[0]
        if policy == "top_down":
            bu = False
        elif policy == "bottom_up":
            bu = True
        else:
            bu = use_bu
        # --- column phase: each owner rank all-gathers its chunk's frontier
        # to the R-1 other ranks in its grid column (direction-independent)
        for q in range(rows * cols):
            ids = frontier[owner[frontier] == q] - q * s
            n_recv = rows - 1
            stats.add("columnCommunication", "raw", "all-gather",
                      raw_col.wire_bytes * n_recv)
            stats.add("columnCommunication", "bitmap", "all-gather",
                      bitmap.wire_bytes * n_recv)
            stats.add("columnCommunication", "packed", "all-gather",
                      _packed_wire_bytes(ladder, ids) * n_recv)
            blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
            stats.add("columnCommunication", "bp128d", "all-gather",
                      len(blob) * n_recv)
        # --- row phase: push exchanges candidate (id, parent) subchunks to
        # owners; pull exchanges found-bitmap + packed parents and folds in
        # the unreached-bitmap all-gather over the grid row.  The exchanged
        # stream is the *candidate* set — every destination with a frontier
        # neighbor, reached or not — which is what the device ladder
        # buckets on (the new frontier alone badly underestimates dense
        # levels, where most of the graph neighbors the frontier).
        e_mask = level[g.src] == lv
        esrc = g.src[e_mask]
        edst = g.dst[e_mask]
        cand = np.unique(edst) if edst.size else np.empty(0, np.int64)
        if bu:
            # pull: only unreached destinations accumulate candidates
            un_mask = (level[edst] > lv) | (level[edst] < 0)
            esrc, edst = esrc[un_mask], edst[un_mask]
        # split candidates by SENDER grid column: the device buckets each
        # sender's per-destination subchunk separately and takes a pmax
        # consensus over the grid row — the union stream per owner chunk
        # underestimates both the counts and the consensus
        key = (esrc // part.n_c) * part.n + edst
        pairs = np.unique(key) if key.size else np.empty(0, np.int64)
        p_col, p_dst = pairs // part.n, pairs % part.n
        p_q = owner[p_dst] if p_dst.size else np.empty(0, np.int64)
        # pairs are sorted by (sender col, dst), so (sender col, chunk)
        # groups are contiguous runs: one searchsorted-style split, no
        # per-pair Python loop
        group = p_col * (rows * cols) + p_q
        cuts = np.flatnonzero(np.diff(group)) + 1
        streams = {}  # (grid row, sender col, owner chunk) -> local ids
        if pairs.size:
            for start, stop in zip(np.r_[0, cuts], np.r_[cuts, pairs.size]):
                jc, q = int(p_col[start]), int(p_q[start])
                streams[(q // cols, jc, q)] = p_dst[start:stop] - q * s

        nxt = np.nonzero(level == lv + 1)[0]
        n_senders = cols - 1
        row_bytes = {f: 0 for f in FORMATS}
        empty = np.empty(0, np.int64)
        if not bu:
            for i in range(rows):
                # grid-row consensus: every rank in the row packs at the
                # bucket of the row's worst (sender, destination) stream
                bkt = max(
                    _host_bucket(row_ladder, streams.get((i, jc, i * cols + k), empty))
                    for jc in range(cols) for k in range(cols)
                )
                unit = _bucket_wire(row_ladder, bkt)[1]
                for k in range(cols):
                    q = i * cols + k
                    row_bytes["raw"] += dense.wire_bytes * n_senders
                    row_bytes["bitmap"] += dense.wire_bytes * n_senders  # parents stay dense
                    row_bytes["packed"] += unit * n_senders
                    for jc in range(cols):
                        if jc == k:
                            continue  # own subchunk never crosses a link
                        ids = streams.get((i, jc, q), empty)
                        blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
                        row_bytes["bp128d"] += len(blob) + 2 * ids.size
        else:
            # per-chunk cost is density-independent, so no per-rank split is
            # needed: baseline stays uncompressed (dense candidates + raw-id
            # unreached gather); compressed formats ride the pull wire
            n_chunks = rows * cols
            bu_wire = (bmp_parent.wire_bytes if bmp_parent else dense.wire_bytes)
            row_bytes["raw"] = (dense.wire_bytes + raw_col.wire_bytes) * n_senders * n_chunks
            for f in ("bitmap", "packed", "bp128d"):
                row_bytes[f] = (bu_wire + bitmap.wire_bytes) * n_senders * n_chunks
        for f in FORMATS:
            stats.add("rowCommunication", f, "all-to-all", row_bytes[f])

        # --- butterfly plan: staged replay of the same candidate streams —
        # per-stage union-merge + re-bucket, plus the staged unreached
        # gather at pull levels
        btfly_bytes = 0
        btfly_stages = []
        for i in range(rows):
            row_streams = {
                (jc, k): streams.get((i, jc, i * cols + k), empty)
                for jc in range(cols) for k in range(cols)
            }
            t, slog = _btfly_row_stage_replay(row_streams, cols, bt_ladder, bt_floor)
            btfly_bytes += t
            for entry in slog:
                btfly_stages.append({"grid_row": i, **entry})
            if bu:
                # padding vertices (>= g.n) stay unreached on device and ride
                # the wire too — include them so buckets match the device
                un_ids = [
                    np.nonzero((level_pad[q * s:(q + 1) * s] > lv)
                               | (level_pad[q * s:(q + 1) * s] < 0))[0]
                    for q in range(i * cols, (i + 1) * cols)
                ]
                t, slog = _btfly_unreached_stage_replay(un_ids, s, cols, ladder)
                btfly_bytes += t
                for entry in slog:
                    btfly_stages.append({"grid_row": i, "zone": "unreached", **entry})

        directions.append(
            {
                "level": lv,
                "direction": "bottom_up" if bu else "top_down",
                "frontier": int(frontier.size),
                "density": frontier.size / part.n,
                "candidates": int(cand.size),
                "row_bytes_packed": row_bytes["packed"],
                "row_bytes_btfly": btfly_bytes,
                "btfly_stages": btfly_stages,
            }
        )
        # next level's direction from the new frontier's count — the same
        # update the device driver threads through the carry
        use_bu = bool(oracle.next_direction(np.int32(nxt.size), bool(use_bu)))

    # predecessor reduction: one dense pass at the end (uncompressed in the
    # paper too — its Table 7.4 shows 0% there)
    for fmt in FORMATS:
        stats.add("predecessorReduction", fmt, "all-gather", 4 * part.n)
    return stats, g, part, directions


def run(scale: int = 17, rows: int = 4, cols: int = 4):
    """-> (table rows with a ``policy`` key, per-policy per-level log)."""
    pol = threshold.ThresholdPolicy()
    table = []
    policy_levels = {}
    prebuilt = build_replay_graph(scale, rows, cols)
    for policy in POLICIES:
        stats, g, part, directions = simulate_zones(
            scale, rows, cols, policy=policy, prebuilt=prebuilt
        )
        policy_levels[policy] = directions
        zones = stats.per_phase_fmt()

        def add_row(zone, fmt, b, raw, plan="alltoall"):
            red = 100.0 * (1 - b / raw) if raw else 0.0
            speedup = pol.modeled_speedup(
                max(raw / 4, 1), ratio=max(raw / max(b, 1), 1.0)
            )
            table.append(
                {
                    "policy": policy,
                    "zone": zone,
                    "format": fmt,
                    "plan": plan,
                    "bytes": b,
                    "reduction_pct": red,
                    "modeled_time_reduction_pct": 100.0 * (1 - 1 / speedup)
                    if (fmt, plan) != ("raw", "alltoall")
                    else 0.0,
                }
            )

        for zone in ZONES:
            fmts = zones[zone]
            raw = fmts["raw"]
            for fmt in FORMATS:
                add_row(zone, fmt, fmts[fmt], raw)
        # the butterfly plan re-compresses per stage; only the row phase
        # differs from the direct plan (column/broadcast zones are shared)
        add_row(
            "rowCommunication",
            "packed",
            sum(d["row_bytes_btfly"] for d in directions),
            zones["rowCommunication"]["raw"],
            plan="btfly",
        )
    return table, policy_levels


def print_table(table: list[dict]) -> None:
    print("policy,zone,format,plan,bytes,data_reduction_pct,modeled_time_reduction_pct")
    for r in table:
        print(f"{r['policy']},{r['zone']},{r['format']},{r['plan']},{r['bytes']},"
              f"{r['reduction_pct']:.2f},{r['modeled_time_reduction_pct']:.2f}")


def print_levels(policy_levels: dict[str, list[dict]]) -> None:
    print("# per-level direction + packed row bytes (direct and butterfly)")
    print("policy,level,direction,frontier,density,row_bytes_packed,row_bytes_btfly")
    for policy, directions in policy_levels.items():
        for d in directions:
            print(f"{policy},{d['level']},{d['direction']},{d['frontier']},"
                  f"{d['density']:.4f},{d['row_bytes_packed']},{d['row_bytes_btfly']}")


def main() -> None:
    table, policy_levels = run()
    print_table(table)
    print_levels(policy_levels)


if __name__ == "__main__":
    main()
