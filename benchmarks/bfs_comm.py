"""Paper Tables 7.4/7.5: per-zone communication volume before/after
compression, and modeled communication-time reduction.

Replays a real multi-rank BFS level by level on the host (numpy), computing
the exact bytes each zone would move under each wire format:

  zones: vertexBroadcast / columnCommunication / rowCommunication /
         predecessorReduction  (the paper's instrumented regions, §4.2.1)

  formats: raw 32-bit ids (Baseline), dense bitmap, bucketed PFOR16 packed
           (the in-graph static-shape codec), and the variable-length
           BP128+delta host codec (the paper's S4-BP128).

Time reduction (Table 7.5 analog) uses the threshold-policy link model —
compress+transmit+decompress at measured codec speeds vs plain transmit.
"""

from __future__ import annotations

import numpy as np

from repro.compression import codecs, collectives as cc, threshold
from repro.core import csr as csrmod
from repro.core import validate
from repro.graphgen import builder, kronecker


def simulate_zones(scale: int = 17, rows: int = 4, cols: int = 4, seed: int = 1):
    """Host replay of the 2D BFS communication; returns per-zone byte counts."""
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    part = bg.part
    s = part.chunk
    wp = 16 if part.n_c <= (1 << 16) else 32
    ladder = cc.BucketLadder.default(s)  # column (membership)
    row_ladder = cc.BucketLadder.default(s, floor_words=s, payload_width=wp)
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)

    zones = {
        "vertexBroadcast": {"raw": 8 * rows * cols, "bitmap": 8 * rows * cols,
                            "packed": 8 * rows * cols, "bp128d": 8 * rows * cols},
        "columnCommunication": {"raw": 0, "bitmap": 0, "packed": 0, "bp128d": 0},
        "rowCommunication": {"raw": 0, "bitmap": 0, "packed": 0, "bp128d": 0},
        "predecessorReduction": {},
    }
    bp = codecs.BP128(delta=True)
    max_level = int(level.max())
    owner = np.minimum(np.arange(part.n) // s, rows * cols - 1)

    for lv in range(max_level):
        frontier = np.nonzero(level == lv)[0]
        # --- column phase: each owner rank all-gathers its chunk's frontier
        # to the R-1 other ranks in its grid column
        for q in range(rows * cols):
            ids = frontier[owner[frontier] == q] - q * s
            n_recv = rows - 1
            zones["columnCommunication"]["raw"] += 4 * s * n_recv  # static cap
            zones["columnCommunication"]["bitmap"] += (s // 8) * n_recv
            counts = ids.size
            exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if counts else 0
            b = int(ladder.bucket_for(np.int32(counts), np.int32(exc)))
            zones["columnCommunication"]["packed"] += 4 * ladder.words_for_branch(b) * n_recv
            blob = bp.encode(ids.astype(np.uint32)) if counts else b""
            zones["columnCommunication"]["bp128d"] += len(blob) * n_recv
        # --- row phase: candidate (id, parent) subchunks to owners
        nxt = np.nonzero(level == lv + 1)[0]
        for q in range(rows * cols):
            ids = nxt[owner[nxt] == q] - q * s
            n_senders = cols - 1
            zones["rowCommunication"]["raw"] += 4 * s * n_senders  # dense int32 cand
            zones["rowCommunication"]["bitmap"] += 4 * s * n_senders  # parents dense
            counts = ids.size
            exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if counts else 0
            b = int(row_ladder.bucket_for(np.int32(counts), np.int32(exc)))
            words = row_ladder.words_for_branch(b, payload_width=wp)
            zones["rowCommunication"]["packed"] += 4 * words * n_senders
            blob = bp.encode(ids.astype(np.uint32)) if counts else b""
            zones["rowCommunication"]["bp128d"] += (len(blob) + 2 * counts) * n_senders

    # predecessor reduction: one dense pass at the end (uncompressed in the
    # paper too — its Table 7.4 shows 0% there)
    pred_bytes = 4 * part.n
    zones["predecessorReduction"] = {k: pred_bytes for k in ("raw", "bitmap", "packed", "bp128d")}
    return zones, g, part


def run(scale: int = 17, rows: int = 4, cols: int = 4):
    zones, g, part = simulate_zones(scale, rows, cols)
    pol = threshold.ThresholdPolicy()
    table = []
    for zone, fmts in zones.items():
        raw = fmts["raw"]
        for fmt, b in fmts.items():
            red = 100.0 * (1 - b / raw) if raw else 0.0
            speedup = pol.modeled_speedup(max(raw / 4, 1), ratio=max(raw / max(b, 1), 1.0))
            table.append(
                {
                    "zone": zone,
                    "format": fmt,
                    "bytes": b,
                    "reduction_pct": red,
                    "modeled_time_reduction_pct": 100.0 * (1 - 1 / speedup)
                    if fmt != "raw"
                    else 0.0,
                }
            )
    return table


def main() -> None:
    print("zone,format,bytes,data_reduction_pct,modeled_time_reduction_pct")
    for r in run():
        print(f"{r['zone']},{r['format']},{r['bytes']},{r['reduction_pct']:.2f},"
              f"{r['modeled_time_reduction_pct']:.2f}")


if __name__ == "__main__":
    main()
