"""Paper Tables 7.4/7.5: per-zone communication volume before/after
compression, and modeled communication-time reduction — now with a
*policy* dimension (direction-optimizing traversal, paper §3.1).

Replays a real multi-rank BFS level by level on the host (numpy),
accumulating the exact bytes each zone would move under each wire format
AND each traversal policy through :class:`repro.comm.CommStats` — the byte
arithmetic lives in the wire formats (:mod:`repro.comm.formats`), not in
this benchmark:

  zones: vertexBroadcast / columnCommunication / rowCommunication /
         predecessorReduction  (the paper's instrumented regions, §4.2.1)

  formats: raw 32-bit ids (Baseline), dense bitmap, bucketed PFOR16 packed
           (the in-graph static-shape codec), and the variable-length
           BP128+delta host codec (the paper's S4-BP128).

  policies: top_down (push ALLTOALLV row phase), bottom_up (pull:
            found-bitmap + bit-packed parents, plus the unreached-bitmap
            all-gather folded into rowCommunication), direction_opt
            (per-level switch on the shared density oracle — the same
            alpha the device driver derives from the bucket ladder).

Time reduction (Table 7.5 analog) uses the threshold-policy link model —
compress+transmit+decompress at measured codec speeds vs plain transmit.
"""

from __future__ import annotations

import numpy as np

from repro.comm import BitmapFormat, BitmapParentFormat, CommStats, DenseFormat, RawIdFormat
from repro.comm.ladder import BucketLadder
from repro.compression import codecs, threshold
from repro.core import csr as csrmod
from repro.core import traversal, validate
from repro.core.distributed_bfs import parent_width_class
from repro.graphgen import builder, kronecker

ZONES = (
    "vertexBroadcast",
    "columnCommunication",
    "rowCommunication",
    "predecessorReduction",
)
FORMATS = ("raw", "bitmap", "packed", "bp128d")
POLICIES = traversal.POLICIES


def _packed_wire_bytes(ladder: BucketLadder, ids: np.ndarray) -> int:
    """Wire bytes of one packed stream under the ladder's bucket choice."""
    count = ids.size
    exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if count else 0
    b = int(ladder.bucket_for(np.int32(count), np.int32(exc)))
    if b < len(ladder.specs):
        return ladder.formats()[b].wire_bytes
    return 4 * ladder.floor_words


def build_replay_graph(scale: int, rows: int, cols: int, seed: int = 1):
    """Graph + partition + reference levels, shared across policy replays
    (the dominant cost — built once, not once per policy)."""
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    return g, bg.part, level


def simulate_zones(
    scale: int = 17, rows: int = 4, cols: int = 4, seed: int = 1,
    policy: str = "top_down", prebuilt=None,
):
    """Host replay of the 2D BFS communication under one traversal policy.

    Returns (stats, g, part, directions): a filled CommStats whose phases
    are the paper's zones and fmts the four wire formats, plus the
    per-level direction/byte log that makes the policy dimension visible
    in BENCH_comm.json.  ``prebuilt`` (from :func:`build_replay_graph`)
    skips the graph/reference rebuild."""
    g, part, level = prebuilt or build_replay_graph(scale, rows, cols, seed)
    s = part.chunk
    wp = parent_width_class(part.n_c)
    ladder = BucketLadder.default(s)  # column (membership vs 1-bit floor)
    row_ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    # the SAME oracle the device driver uses: direction flips where the row
    # ladder's sparse capacities run out
    oracle = traversal.DensityOracle(part.n, alpha=traversal.ladder_alpha(s, wp))

    stats = CommStats()
    raw_col = RawIdFormat(s)
    bitmap = BitmapFormat(s)
    dense = DenseFormat(s)
    bmp_parent = BitmapParentFormat(s, wp) if wp < 32 else None
    bp = codecs.BP128(delta=True)
    for fmt in FORMATS:  # root broadcast: 8 bytes to every rank, any format
        stats.add("vertexBroadcast", fmt, "all-gather", 8 * rows * cols)
    max_level = int(level.max())
    owner = np.minimum(np.arange(part.n) // s, rows * cols - 1)

    use_bu = policy == "bottom_up"  # host mirror of the carry's use_bu flag
    directions = []
    for lv in range(max_level):
        frontier = np.nonzero(level == lv)[0]
        if policy == "top_down":
            bu = False
        elif policy == "bottom_up":
            bu = True
        else:
            bu = use_bu
        # --- column phase: each owner rank all-gathers its chunk's frontier
        # to the R-1 other ranks in its grid column (direction-independent)
        for q in range(rows * cols):
            ids = frontier[owner[frontier] == q] - q * s
            n_recv = rows - 1
            stats.add("columnCommunication", "raw", "all-gather",
                      raw_col.wire_bytes * n_recv)
            stats.add("columnCommunication", "bitmap", "all-gather",
                      bitmap.wire_bytes * n_recv)
            stats.add("columnCommunication", "packed", "all-gather",
                      _packed_wire_bytes(ladder, ids) * n_recv)
            blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
            stats.add("columnCommunication", "bp128d", "all-gather",
                      len(blob) * n_recv)
        # --- row phase: push exchanges candidate (id, parent) subchunks to
        # owners; pull exchanges found-bitmap + packed parents and folds in
        # the unreached-bitmap all-gather over the grid row.  The exchanged
        # stream is the *candidate* set — every destination with a frontier
        # neighbor, reached or not — which is what the device ladder
        # buckets on (the new frontier alone badly underestimates dense
        # levels, where most of the graph neighbors the frontier).
        e_mask = level[g.src] == lv
        cand = np.unique(g.dst[e_mask]) if e_mask.any() else np.empty(0, np.int64)
        nxt = np.nonzero(level == lv + 1)[0]
        n_senders = cols - 1
        row_bytes = {f: 0 for f in FORMATS}
        if not bu:
            for q in range(rows * cols):
                ids = cand[owner[cand] == q] - q * s
                row_bytes["raw"] += dense.wire_bytes * n_senders
                row_bytes["bitmap"] += dense.wire_bytes * n_senders  # parents stay dense
                row_bytes["packed"] += _packed_wire_bytes(row_ladder, ids) * n_senders
                blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
                row_bytes["bp128d"] += (len(blob) + 2 * ids.size) * n_senders
        else:
            # per-chunk cost is density-independent, so no per-rank split is
            # needed: baseline stays uncompressed (dense candidates + raw-id
            # unreached gather); compressed formats ride the pull wire
            n_chunks = rows * cols
            bu_wire = (bmp_parent.wire_bytes if bmp_parent else dense.wire_bytes)
            row_bytes["raw"] = (dense.wire_bytes + raw_col.wire_bytes) * n_senders * n_chunks
            for f in ("bitmap", "packed", "bp128d"):
                row_bytes[f] = (bu_wire + bitmap.wire_bytes) * n_senders * n_chunks
        for f in FORMATS:
            stats.add("rowCommunication", f, "all-to-all", row_bytes[f])
        directions.append(
            {
                "level": lv,
                "direction": "bottom_up" if bu else "top_down",
                "frontier": int(frontier.size),
                "density": frontier.size / part.n,
                "candidates": int(cand.size),
                "row_bytes_packed": row_bytes["packed"],
            }
        )
        # next level's direction from the new frontier's count — the same
        # update the device driver threads through the carry
        use_bu = bool(oracle.next_direction(np.int32(nxt.size), bool(use_bu)))

    # predecessor reduction: one dense pass at the end (uncompressed in the
    # paper too — its Table 7.4 shows 0% there)
    for fmt in FORMATS:
        stats.add("predecessorReduction", fmt, "all-gather", 4 * part.n)
    return stats, g, part, directions


def run(scale: int = 17, rows: int = 4, cols: int = 4):
    """-> (table rows with a ``policy`` key, per-policy per-level log)."""
    pol = threshold.ThresholdPolicy()
    table = []
    policy_levels = {}
    prebuilt = build_replay_graph(scale, rows, cols)
    for policy in POLICIES:
        stats, g, part, directions = simulate_zones(
            scale, rows, cols, policy=policy, prebuilt=prebuilt
        )
        policy_levels[policy] = directions
        zones = stats.per_phase_fmt()
        for zone in ZONES:
            fmts = zones[zone]
            raw = fmts["raw"]
            for fmt in FORMATS:
                b = fmts[fmt]
                red = 100.0 * (1 - b / raw) if raw else 0.0
                speedup = pol.modeled_speedup(
                    max(raw / 4, 1), ratio=max(raw / max(b, 1), 1.0)
                )
                table.append(
                    {
                        "policy": policy,
                        "zone": zone,
                        "format": fmt,
                        "bytes": b,
                        "reduction_pct": red,
                        "modeled_time_reduction_pct": 100.0 * (1 - 1 / speedup)
                        if fmt != "raw"
                        else 0.0,
                    }
                )
    return table, policy_levels


def print_table(table: list[dict]) -> None:
    print("policy,zone,format,bytes,data_reduction_pct,modeled_time_reduction_pct")
    for r in table:
        print(f"{r['policy']},{r['zone']},{r['format']},{r['bytes']},"
              f"{r['reduction_pct']:.2f},{r['modeled_time_reduction_pct']:.2f}")


def print_levels(policy_levels: dict[str, list[dict]]) -> None:
    print("# per-level direction + packed row bytes")
    print("policy,level,direction,frontier,density,row_bytes_packed")
    for policy, directions in policy_levels.items():
        for d in directions:
            print(f"{policy},{d['level']},{d['direction']},{d['frontier']},"
                  f"{d['density']:.4f},{d['row_bytes_packed']}")


def main() -> None:
    table, policy_levels = run()
    print_table(table)
    print_levels(policy_levels)


if __name__ == "__main__":
    main()
