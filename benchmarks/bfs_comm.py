"""Paper Tables 7.4/7.5: per-zone communication volume before/after
compression, and modeled communication-time reduction — now with a
*policy* dimension (direction-optimizing traversal, paper §3.1).

Replays a real multi-rank BFS level by level on the host (numpy),
accumulating the exact bytes each zone would move under each wire format
AND each traversal policy through :class:`repro.comm.CommStats` — the byte
arithmetic lives in the wire formats (:mod:`repro.comm.formats`), not in
this benchmark:

  zones: vertexBroadcast / columnCommunication / rowCommunication /
         predecessorReduction  (the paper's instrumented regions, §4.2.1)

  formats: raw 32-bit ids (Baseline), dense bitmap, bucketed PFOR16 packed
           (the in-graph static-shape codec), and the variable-length
           BP128+delta host codec (the paper's S4-BP128).

  policies: top_down (push ALLTOALLV row phase), bottom_up (pull:
            found-bitmap + bit-packed parents, plus the unreached-bitmap
            all-gather folded into rowCommunication), direction_opt
            (per-level switch on the shared density oracle — the same
            alpha the device driver derives from the bucket ladder).

  plans: alltoall (the direct row exchange) vs btfly (ButterFly BFS:
         log2(C) staged ppermute rounds whose merged stream is re-bucketed
         per hop — the replay mirrors the device stage schedule, logs each
         stage's consensus format, and its bytes must reconcile with the
         static byte model; scripts/check_bench_comm.py enforces that).

The row phase buckets each (sender column, destination chunk) stream
separately and takes the max over the grid row — the device's pmax
consensus — NOT the union stream per owner chunk, which underestimates
both the counts and the consensus escalation.

Time reduction (Table 7.5 analog) uses the threshold-policy link model —
compress+transmit+decompress at measured codec speeds vs plain transmit.
"""

from __future__ import annotations

import numpy as np

from repro.comm import BitmapFormat, BitmapParentFormat, CommStats, DenseFormat, RawIdFormat
from repro.comm import butterfly
from repro.comm.formats import plane_wire_bytes
from repro.comm.ladder import BucketLadder
from repro.comm import codecs, threshold
from repro.core import bfs as bfs_core
from repro.core import csr as csrmod
from repro.core import traversal, validate
from repro.core.distributed_bfs import parent_width_class
from repro.graphgen import builder, kronecker

ZONES = (
    "vertexBroadcast",
    "columnCommunication",
    "rowCommunication",
    "predecessorReduction",
)
FORMATS = ("raw", "bitmap", "packed", "bp128d")
#: exchange plans of the row phase: the direct ALLTOALLV and the staged
#: butterfly (log2(C) ppermute rounds, merged stream re-bucketed per hop)
PLANS = ("alltoall", "btfly")
POLICIES = traversal.POLICIES


def _host_bucket(ladder: BucketLadder, ids: np.ndarray) -> int:
    """The ladder's bucket for one sorted id stream (host mirror of
    ``BucketLadder.bucket_for`` — smallest spec whose id and exception
    capacities both fit)."""
    count = ids.size
    exc = int((codecs.delta_encode(ids.astype(np.uint32)) >> 16 > 0).sum()) if count else 0
    for i, spec in enumerate(ladder.specs):
        if count <= spec.cap and exc <= spec.exc_cap:
            return i
    return len(ladder.specs)


def _bucket_wire(ladder: BucketLadder, bucket: int, floor_fmt=None, b: int = 1):
    """(format name, wire bytes of all ``b`` planes) of one subchunk at
    ``bucket`` — dense floors scale linearly, id streams share the plane
    header (:func:`repro.comm.plane_wire_bytes`)."""
    if bucket < len(ladder.specs):
        fmt = ladder.formats()[bucket]
        return fmt.name, plane_wire_bytes(fmt, b)
    if floor_fmt is not None:
        return floor_fmt.name, plane_wire_bytes(floor_fmt, b)
    return "bitmap", b * 4 * ladder.floor_words


def _packed_wire_bytes(ladder: BucketLadder, ids: np.ndarray) -> int:
    """Wire bytes of one packed stream under the ladder's bucket choice."""
    return _bucket_wire(ladder, _host_bucket(ladder, ids))[1]


def _btfly_row_stage_replay(streams, cols: int, ladder: BucketLadder,
                            floor_fmt, b: int = 1):
    """Host replay of the butterfly row phase over ONE grid row.

    ``streams[(j, k)]``: for ``b == 1``, the sorted local candidate ids
    sender column ``j`` holds for the row's ``k``-th destination chunk; for
    a multi-source batch, a length-``b`` list of per-plane id arrays.
    Mirrors the device schedule exactly — fold, log2(P) pairwise stages,
    unfold — including the per-stage row-wide format consensus (max bucket
    over every subchunk AND plane on the wire that stage) and the per-plane
    union-merge that the next stage re-buckets.  Stage bytes price all
    planes at the shared-header plane wire
    (:func:`repro.comm.butterfly.stage_unit_bytes` with ``b``).
    Returns (total bytes, stage log)."""
    sched = butterfly.ButterflySchedule(cols)
    p, extra, slots = sched.p, sched.extra, sched.slots
    empty = np.empty(0, np.int64)

    def planes_of(j, q):
        v = streams.get((j, q))
        if v is None:
            return [empty] * b
        return [v] if b == 1 and not isinstance(v, list) else v

    def leaf_streams(j):
        rows_ = {}
        for r in range(p):
            rows_[(r, 0)] = planes_of(j, r)
            if slots == 2:
                rows_[(r, 1)] = (
                    planes_of(j, p + r) if r < extra else [empty] * b
                )
        return rows_

    state = {j: leaf_streams(j) for j in range(cols)}
    total = 0
    log = []

    def do_exchange(label, sends):
        """sends: list of (src, dst, [leaf keys]) — consensus + merge."""
        nonlocal total
        blocks = {src: [state[src][key] for key in keys] for src, dst, keys in sends}
        bucket = max(
            (_host_bucket(ladder, ids) for blk in blocks.values()
             for planes in blk for ids in planes),
            default=0,
        )
        fmt, unit = _bucket_wire(ladder, bucket, floor_fmt, b=b)
        n_sub = len(sends[0][2])
        assert all(len(keys) == n_sub for _, _, keys in sends)
        nbytes = len(sends) * n_sub * unit
        total += nbytes
        entry = {"stage": label, "fmt": fmt, "senders": len(sends),
                 "subchunks": n_sub, "bytes": nbytes}
        if b > 1:
            entry["batch"] = b
        log.append(entry)
        merged = {}
        for src, dst, keys in sends:
            for key in keys:
                merged.setdefault(dst, {})[key] = [
                    np.union1d(d, s_)
                    for d, s_ in zip(state[dst][key], state[src][key])
                ]
        for dst, upd in merged.items():
            state[dst].update(upd)

    all_leaves = [(r, sl) for r in range(p) for sl in range(slots)]
    if extra:
        do_exchange(
            "fold", [(p + e, e, all_leaves) for e in range(extra)]
        )
    for t in range(sched.n_stages):
        m = 1 << t
        sends = []
        for j in range(p):
            send_rows = [((j ^ m) & (2 * m - 1)) + 2 * m * i
                         for i in range(sched.stage_blocks(t))]
            keys = [(r, sl) for r in send_rows for sl in range(slots)]
            sends.append((j, j ^ m, keys))
        do_exchange(str(t), sends)
    if extra:
        do_exchange(
            "unfold", [(e, p + e, [(e, 1)]) for e in range(extra)]
        )
    return total, log


def _btfly_unreached_stage_replay(chunk_ids, s: int, cols: int,
                                  ladder: BucketLadder, b: int = 1):
    """Host replay of the staged unreached all-gather over one grid row.

    ``chunk_ids[k]``: sorted local unreached ids of the row's ``k``-th
    chunk (a length-``b`` list of per-plane arrays when batched).  The
    doubling block keeps chunk identity, so per-subchunk buckets never
    change — only the block size per stage does."""
    sched = butterfly.ButterflySchedule(cols)
    p, extra, slots = sched.p, sched.extra, sched.slots
    bitmap = BitmapFormat(s)
    empty = np.empty(0, np.int64)

    def leaf_planes(r, sl):
        q = r if sl == 0 else p + r
        if sl == 1 and r >= extra:
            return [empty] * b
        v = chunk_ids[q]
        return [v] if b == 1 and not isinstance(v, list) else v

    total = 0
    log = []

    def do_exchange(label, n_senders, leaf_sets):
        nonlocal total
        bucket = max(
            (_host_bucket(ladder, ids) for leaves in leaf_sets
             for r, sl in leaves for ids in leaf_planes(r, sl)),
            default=0,
        )
        fmt, unit = _bucket_wire(ladder, bucket, bitmap, b=b)
        n_sub = len(leaf_sets[0])
        nbytes = n_senders * n_sub * unit
        total += nbytes
        entry = {"stage": label, "fmt": fmt, "senders": n_senders,
                 "subchunks": n_sub, "bytes": nbytes}
        if b > 1:
            entry["batch"] = b
        log.append(entry)

    if extra:
        do_exchange("fold", extra, [[(e, 1)] for e in range(extra)])
    for t in range(sched.n_stages):
        blk = 1 << t
        sets = []
        for j in range(p):
            start = (j >> t) << t
            sets.append([(start + i, sl) for i in range(blk)
                         for sl in range(slots)])
        do_exchange(str(t), p, sets)
    if extra:
        all_leaves = [(r, sl) for r in range(p) for sl in range(slots)]
        do_exchange("unfold", extra, [all_leaves for _ in range(extra)])
    return total, log


def build_replay_graph(scale: int, rows: int, cols: int, seed: int = 1):
    """Graph + partition + reference levels, shared across policy replays
    (the dominant cost — built once, not once per policy)."""
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    return g, bg.part, level


def _sender_split_streams(level_vec, lv, bu, g, part, owner):
    """Candidate streams of one source plane at one level, split by sender.

    The exchanged stream is the *candidate* set — every destination with a
    frontier neighbor (pull levels: unreached destinations only) — split per
    SENDER grid column, the granularity the device buckets on before its
    grid-row pmax consensus (the union stream per owner chunk underestimates
    both the counts and the consensus).  Shared by the single-source and the
    multi-source replays so the two byte models cannot drift.

    Returns ``({(grid row, sender col, owner chunk) -> local ids},
    candidate count before the pull mask)``.
    """
    empty = np.empty(0, np.int64)
    e_mask = level_vec[g.src] == lv
    esrc, edst = g.src[e_mask], g.dst[e_mask]
    n_cand = int(np.unique(edst).size) if edst.size else 0
    if bu:
        un_mask = (level_vec[edst] > lv) | (level_vec[edst] < 0)
        esrc, edst = esrc[un_mask], edst[un_mask]
    key = (esrc // part.n_c) * part.n + edst
    pairs = np.unique(key) if key.size else empty
    p_col, p_dst = pairs // part.n, pairs % part.n
    p_q = owner[p_dst] if p_dst.size else empty
    # pairs are sorted by (sender col, dst), so (sender col, chunk) groups
    # are contiguous runs: one searchsorted-style split, no per-pair loop
    group = p_col * (part.rows * part.cols) + p_q
    cuts = np.flatnonzero(np.diff(group)) + 1
    streams = {}
    if pairs.size:
        for start, stop in zip(np.r_[0, cuts], np.r_[cuts, pairs.size]):
            jc, q = int(p_col[start]), int(p_q[start])
            streams[(q // part.cols, jc, q)] = p_dst[start:stop] - q * part.chunk
    return streams, n_cand


def simulate_zones(
    scale: int = 17, rows: int = 4, cols: int = 4, seed: int = 1,
    policy: str = "top_down", prebuilt=None,
):
    """Host replay of the 2D BFS communication under one traversal policy.

    Returns (stats, g, part, directions): a filled CommStats whose phases
    are the paper's zones and fmts the four wire formats, plus the
    per-level direction/byte log that makes the policy dimension visible
    in BENCH_comm.json.  ``prebuilt`` (from :func:`build_replay_graph`)
    skips the graph/reference rebuild."""
    g, part, level = prebuilt or build_replay_graph(scale, rows, cols, seed)
    s = part.chunk
    wp = parent_width_class(part.n_c)
    ladder = BucketLadder.default(s)  # column (membership vs 1-bit floor)
    row_ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    # the butterfly's row wire: global-parent payload class + its dense
    # floor (found-bitmap + packed parents) — the same geometry the device
    # plan builds, so stage formats reconcile with the static byte model
    bt_ladder, bt_floor = butterfly.row_wire(s, part.n)
    # the SAME oracle the device driver uses: direction flips where the row
    # ladder's sparse capacities run out
    oracle = traversal.DensityOracle(part.n, alpha=traversal.ladder_alpha(s, wp))

    stats = CommStats()
    raw_col = RawIdFormat(s)
    bitmap = BitmapFormat(s)
    dense = DenseFormat(s)
    bmp_parent = BitmapParentFormat(s, wp) if wp < 32 else None
    bp = codecs.BP128(delta=True)
    for fmt in FORMATS:  # root broadcast: 8 bytes to every rank, any format
        stats.add("vertexBroadcast", fmt, "all-gather", 8 * rows * cols)
    max_level = int(level.max())
    owner = np.minimum(np.arange(part.n) // s, rows * cols - 1)
    level_pad = np.full(part.n, -1, level.dtype)
    level_pad[: g.n] = level
    deg = g.degrees()  # anticipatory oracle: Beamer m_f from the degree dot

    use_bu = policy == "bottom_up"  # host mirror of the carry's use_bu flag
    directions = []
    for lv in range(max_level):
        frontier = np.nonzero(level == lv)[0]
        if policy == "top_down":
            bu = False
        elif policy == "bottom_up":
            bu = True
        else:
            bu = use_bu
        # --- column phase: each owner rank all-gathers its chunk's frontier
        # to the R-1 other ranks in its grid column (direction-independent)
        for q in range(rows * cols):
            ids = frontier[owner[frontier] == q] - q * s
            n_recv = rows - 1
            stats.add("columnCommunication", "raw", "all-gather",
                      raw_col.wire_bytes * n_recv)
            stats.add("columnCommunication", "bitmap", "all-gather",
                      bitmap.wire_bytes * n_recv)
            stats.add("columnCommunication", "packed", "all-gather",
                      _packed_wire_bytes(ladder, ids) * n_recv)
            blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
            stats.add("columnCommunication", "bp128d", "all-gather",
                      len(blob) * n_recv)
        # --- row phase: push exchanges candidate (id, parent) subchunks to
        # owners; pull exchanges found-bitmap + packed parents and folds in
        # the unreached-bitmap all-gather over the grid row.  Candidate-set
        # sizing and the per-sender split live in _sender_split_streams
        # (the new frontier alone badly underestimates dense levels, where
        # most of the graph neighbors the frontier).
        streams, n_cand = _sender_split_streams(level, lv, bu, g, part, owner)

        nxt = np.nonzero(level == lv + 1)[0]
        n_senders = cols - 1
        row_bytes = {f: 0 for f in FORMATS}
        empty = np.empty(0, np.int64)
        if not bu:
            for i in range(rows):
                # grid-row consensus: every rank in the row packs at the
                # bucket of the row's worst (sender, destination) stream
                bkt = max(
                    _host_bucket(row_ladder, streams.get((i, jc, i * cols + k), empty))
                    for jc in range(cols) for k in range(cols)
                )
                unit = _bucket_wire(row_ladder, bkt)[1]
                for k in range(cols):
                    q = i * cols + k
                    row_bytes["raw"] += dense.wire_bytes * n_senders
                    row_bytes["bitmap"] += dense.wire_bytes * n_senders  # parents stay dense
                    row_bytes["packed"] += unit * n_senders
                    for jc in range(cols):
                        if jc == k:
                            continue  # own subchunk never crosses a link
                        ids = streams.get((i, jc, q), empty)
                        blob = bp.encode(ids.astype(np.uint32)) if ids.size else b""
                        row_bytes["bp128d"] += len(blob) + 2 * ids.size
        else:
            # per-chunk cost is density-independent, so no per-rank split is
            # needed: baseline stays uncompressed (dense candidates + raw-id
            # unreached gather); compressed formats ride the pull wire
            n_chunks = rows * cols
            bu_wire = (bmp_parent.wire_bytes if bmp_parent else dense.wire_bytes)
            row_bytes["raw"] = (dense.wire_bytes + raw_col.wire_bytes) * n_senders * n_chunks
            for f in ("bitmap", "packed", "bp128d"):
                row_bytes[f] = (bu_wire + bitmap.wire_bytes) * n_senders * n_chunks
        for f in FORMATS:
            stats.add("rowCommunication", f, "all-to-all", row_bytes[f])

        # --- butterfly plan: staged replay of the same candidate streams —
        # per-stage union-merge + re-bucket, plus the staged unreached
        # gather at pull levels
        btfly_bytes = 0
        btfly_stages = []
        for i in range(rows):
            row_streams = {
                (jc, k): streams.get((i, jc, i * cols + k), empty)
                for jc in range(cols) for k in range(cols)
            }
            t, slog = _btfly_row_stage_replay(row_streams, cols, bt_ladder, bt_floor)
            btfly_bytes += t
            for entry in slog:
                btfly_stages.append({"grid_row": i, **entry})
            if bu:
                # padding vertices (>= g.n) stay unreached on device and ride
                # the wire too — include them so buckets match the device
                un_ids = [
                    np.nonzero((level_pad[q * s:(q + 1) * s] > lv)
                               | (level_pad[q * s:(q + 1) * s] < 0))[0]
                    for q in range(i * cols, (i + 1) * cols)
                ]
                t, slog = _btfly_unreached_stage_replay(un_ids, s, cols, ladder)
                btfly_bytes += t
                for entry in slog:
                    btfly_stages.append({"grid_row": i, "zone": "unreached", **entry})

        directions.append(
            {
                "level": lv,
                "direction": "bottom_up" if bu else "top_down",
                "frontier": int(frontier.size),
                "density": frontier.size / part.n,
                "candidates": n_cand,
                "row_bytes_packed": row_bytes["packed"],
                "row_bytes_btfly": btfly_bytes,
                "btfly_stages": btfly_stages,
            }
        )
        # next level's direction from the new frontier's count plus the
        # anticipatory m_f/m_u edge signals — the same psum'd update the
        # device driver threads through the carry (direction_opt only; the
        # fixed policies never consult the oracle)
        m_f = m_u = growing = None
        if policy == "direction_opt":
            m_f = int(deg[level == lv + 1].sum())
            m_u = int(deg[(level < 0) | (level > lv + 1)].sum())
            growing = nxt.size > frontier.size
        use_bu = bool(
            oracle.next_direction(np.int32(nxt.size), bool(use_bu),
                                  m_f=m_f, m_u=m_u, growing=growing)
        )

    # predecessor reduction: one dense pass at the end (uncompressed in the
    # paper too — its Table 7.4 shows 0% there)
    for fmt in FORMATS:
        stats.add("predecessorReduction", fmt, "all-gather", 4 * part.n)
    return stats, g, part, directions


#: batch width of the multi-source bench section (the B=4 acceptance row)
BATCH_B = 4


def batch_roots(g, n_roots: int) -> np.ndarray:
    """The ``B`` highest-degree hub roots (one convention for the whole
    repo: :func:`repro.core.bfs.hub_roots`)."""
    return bfs_core.hub_roots(g.degrees(), n_roots)


def simulate_batch(
    scale: int, rows: int, cols: int, n_src: int,
    policy: str = "direction_opt", seed: int = 1, graph=None,
    level_cache=None,
):
    """Host replay of the MULTI-SOURCE packed-wire communication model.

    Replays one batched BFS with ``n_src`` source planes level by level,
    mirroring the device driver: per-plane directions from the shared
    oracle (including the anticipatory Beamer ``m_f`` signal), one bucket
    consensus per exchange taken as the max over every plane, and plane
    wire pricing from :func:`repro.comm.plane_wire_bytes` (dense floors
    linear, id-stream headers shared).  Returns a dict with per-plan totals
    for the two row-phase plans plus the shared zones, in cluster-total
    bytes — the same convention :func:`simulate_zones` uses — so
    ``bytes_per_source`` at B=4 is directly comparable with a B=1 replay of
    the same model.
    """
    g = graph or builder.build_csr(
        kronecker.kronecker_edges(scale, seed=seed), n=1 << scale
    )
    n_pad, _ = csrmod.padded_geometry(g.n, rows, cols)
    part = csrmod.Partition2D(n=n_pad, n_orig=g.n, rows=rows, cols=cols)
    s = part.chunk
    ranks = rows * cols
    b = n_src
    roots = batch_roots(g, b)
    if level_cache is None:
        level_cache = {}
    levels = [
        level_cache.setdefault(int(r), validate.reference_bfs(g, int(r)))
        for r in roots
    ]
    dpad = np.zeros(part.n, np.int64)  # degree vector at padded geometry
    dpad[: g.n] = g.degrees()
    wp = parent_width_class(part.n_c)
    col_ladder = BucketLadder.default(s)
    row_ladder = BucketLadder.default(s, floor_words=s, payload_width=wp)
    bt_ladder, bt_floor = butterfly.row_wire(s, part.n)
    un_ladder, _ = butterfly.unreached_wire(s)
    oracle = traversal.DensityOracle(part.n, alpha=traversal.ladder_alpha(s, wp))
    bitmap = BitmapFormat(s)
    bmp_parent = BitmapParentFormat(s, wp) if wp < 32 else DenseFormat(s)
    owner = np.minimum(np.arange(part.n) // s, ranks - 1)
    level_pad = [np.full(part.n, -1, lv.dtype) for lv in levels]
    for k, lv in enumerate(levels):
        level_pad[k][: g.n] = lv
    adaptive = policy == "direction_opt"
    max_level = max(int(lv.max()) for lv in levels)
    empty = np.empty(0, np.int64)

    zones = {
        # one broadcast carries all B roots (4 bytes each) to every rank
        "broadcast": 4 * b * ranks,
        "column": 0, "row": {"alltoall": 0, "btfly": 0}, "transpose": 0,
        "termination": 0, "degree": 0, "consensus": {"alltoall": 0, "btfly": 0},
        "reduction": 4 * part.n * b,
    }
    btfly_stages = []
    if adaptive:
        # the anticipatory oracle's one-time owned-degree psum (grid-row
        # all-reduce of n_r ints, HLO-doubled), shared by every plane
        zones["degree"] = 8 * part.n_r * ranks

    use_bu = [policy == "bottom_up"] * b
    for lv in range(max_level):
        frontiers = [np.nonzero(lp == lv)[0] for lp in level_pad]
        act = [f.size > 0 for f in frontiers]
        if policy == "top_down":
            bu = [False] * b
        elif policy == "bottom_up":
            bu = [True] * b
        else:
            bu = list(use_bu)
        # --- transpose: all B planes ride one (B, s)-bool permute per rank
        zones["transpose"] += b * s * ranks
        # --- termination psum: (B,) counts (+ m_f/m_u planes when adaptive)
        zones["termination"] += (3 if adaptive else 1) * 8 * b * ranks
        # --- column phase: per owner chunk, bucket = max over planes
        for q in range(ranks):
            plane_ids = [
                f[owner[f] == q] - q * s if a else empty
                for f, a in zip(frontiers, act)
            ]
            bkt = max(_host_bucket(col_ladder, ids) for ids in plane_ids)
            unit = _bucket_wire(col_ladder, bkt, bitmap, b=b)[1]
            zones["column"] += unit * (rows - 1)
        if col_ladder.specs:
            for plan in ("alltoall", "btfly"):
                zones["consensus"][plan] += 8 * cols  # one per column group
        # --- row phase: the same per-sender candidate split as the
        # single-source replay (_sender_split_streams), keyed per plane and
        # routed to the wire of each plane's direction
        push_streams = {}
        pull_streams = {}
        un_ids = None
        for k in range(b):
            if not act[k]:
                continue
            streams_k, _ = _sender_split_streams(
                level_pad[k], lv, bu[k], g, part, owner
            )
            target = pull_streams if bu[k] else push_streams
            for site, ids in streams_k.items():
                target.setdefault(site, {})[k] = ids
        push_active = any(a and not d for a, d in zip(act, bu))
        pull_active = any(a and d for a, d in zip(act, bu))

        def plane_list(streams, i, jc, q):
            per = streams.get((i, jc, q), {})
            return [per.get(k, empty) for k in range(b)]

        n_senders = cols - 1
        if push_active:
            # direct plan: one consensus per grid row, every chunk pays the
            # row's worst (sender, destination, plane) bucket
            for i in range(rows):
                bkt = max(
                    _host_bucket(row_ladder, ids)
                    for jc in range(cols) for kq in range(cols)
                    for ids in plane_list(push_streams, i, jc, i * cols + kq)
                )
                unit = _bucket_wire(row_ladder, bkt, b=b)[1]
                zones["row"]["alltoall"] += unit * n_senders * cols
            zones["consensus"]["alltoall"] += 8 * rows
            # butterfly plan: staged replay of the same plane streams
            for i in range(rows):
                row_streams = {
                    (jc, kq): plane_list(push_streams, i, jc, i * cols + kq)
                    for jc in range(cols) for kq in range(cols)
                }
                t, slog = _btfly_row_stage_replay(
                    row_streams, cols, bt_ladder, bt_floor, b=b
                )
                zones["row"]["btfly"] += t
                zones["consensus"]["btfly"] += 8 * len(slog)
                for entry in slog:
                    btfly_stages.append({"grid_row": i, "level": lv, **entry})
        if pull_active:
            # pull wire is density-independent: every plane pays the
            # found-bitmap + packed-parent unit plus the unreached gather
            pull_unit = plane_wire_bytes(bmp_parent, b)
            gather_unit = plane_wire_bytes(bitmap, b)
            zones["row"]["alltoall"] += (pull_unit + gather_unit) * n_senders * ranks
            un_ids = [
                [
                    np.nonzero(
                        ((level_pad[k][q * s:(q + 1) * s] > lv)
                         | (level_pad[k][q * s:(q + 1) * s] < 0))
                        if bu[k] and act[k]
                        else np.zeros(s, bool)
                    )[0]
                    for k in range(b)
                ]
                for q in range(ranks)
            ]
            for i in range(rows):
                row_streams = {
                    (jc, kq): plane_list(pull_streams, i, jc, i * cols + kq)
                    for jc in range(cols) for kq in range(cols)
                }
                t, slog = _btfly_row_stage_replay(
                    row_streams, cols, bt_ladder, bt_floor, b=b
                )
                zones["row"]["btfly"] += t
                zones["consensus"]["btfly"] += 8 * len(slog)
                for entry in slog:
                    btfly_stages.append(
                        {"grid_row": i, "level": lv, "zone": "row-pull", **entry}
                    )
                t, slog = _btfly_unreached_stage_replay(
                    un_ids[i * cols:(i + 1) * cols], s, cols, un_ladder, b=b
                )
                zones["row"]["btfly"] += t
                zones["consensus"]["btfly"] += 8 * len(slog)
                for entry in slog:
                    btfly_stages.append(
                        {"grid_row": i, "level": lv, "zone": "unreached", **entry}
                    )
        # --- next level's per-plane direction: the same psum'd signals the
        # device threads through the carry
        for k in range(b):
            nxt = np.nonzero(level_pad[k] == lv + 1)[0]
            m_f = m_u = growing = None
            if adaptive:
                nxt_mask = level_pad[k] == lv + 1
                un_mask = (level_pad[k] < 0) | (level_pad[k] > lv + 1)
                m_f = int(dpad[nxt_mask].sum())
                m_u = int(dpad[un_mask].sum())
                growing = nxt.size > frontiers[k].size
            use_bu[k] = bool(
                oracle.next_direction(np.int32(nxt.size), bool(use_bu[k]),
                                      m_f=m_f, m_u=m_u, growing=growing)
            )

    shared = (zones["broadcast"] + zones["column"] + zones["transpose"]
              + zones["termination"] + zones["degree"] + zones["reduction"])
    plans = {}
    for plan in ("alltoall", "btfly"):
        total = shared + zones["row"][plan] + zones["consensus"][plan]
        plans[plan] = {
            "row_bytes": zones["row"][plan],
            "consensus_bytes": zones["consensus"][plan],
            "total_bytes": total,
            "bytes_per_source": total / b,
        }
    return {
        "B": b,
        "policy": policy,
        "roots": [int(r) for r in roots],
        "zones": {k: v for k, v in zones.items() if k not in ("row", "consensus")},
        "plans": plans,
        "btfly_stages": btfly_stages,
    }


def run_batch(scale: int = 15, rows: int = 2, cols: int = 2,
              n_src: int = BATCH_B, prebuilt=None):
    """Batched-vs-single packed-wire comparison for BENCH_comm.json.

    For each policy: a B=``n_src`` multi-source replay and the B=1 replay
    of the SAME model (same root = the argmax-degree hub), per row-phase
    plan.  The acceptance invariant — ``bytes_per_source`` at B=4 strictly
    below the B=1 total, for both plans — is enforced by
    ``scripts/check_bench_comm.py`` in CI.  ``prebuilt`` (from
    :func:`build_replay_graph`) shares the graph AND the hub root's
    reference levels with the single-source replay suite.
    """
    cache = {}  # root -> reference levels, shared by every replay of g
    if prebuilt is not None:
        g, _, hub_level = prebuilt
        cache[int(np.argmax(g.degrees()))] = hub_level
    else:
        g = builder.build_csr(
            kronecker.kronecker_edges(scale, seed=1), n=1 << scale
        )
    out = {"B": n_src, "policies": {}}
    for policy in POLICIES:
        batched = simulate_batch(scale, rows, cols, n_src, policy=policy,
                                 graph=g, level_cache=cache)
        single = simulate_batch(scale, rows, cols, 1, policy=policy,
                                graph=g, level_cache=cache)
        entry = {"roots": batched["roots"], "zones": batched["zones"],
                 "plans": {}}
        for plan in ("alltoall", "btfly"):
            entry["plans"][plan] = {
                "batch": n_src,
                "row_bytes": batched["plans"][plan]["row_bytes"],
                "total_bytes": batched["plans"][plan]["total_bytes"],
                "bytes_per_source": batched["plans"][plan]["bytes_per_source"],
                "b1_total_bytes": single["plans"][plan]["total_bytes"],
            }
        entry["btfly_stages"] = batched["btfly_stages"]
        out["policies"][policy] = entry
    return out


def run(scale: int = 17, rows: int = 4, cols: int = 4, prebuilt=None):
    """-> (table rows with a ``policy`` key, per-policy per-level log)."""
    pol = threshold.ThresholdPolicy()
    table = []
    policy_levels = {}
    prebuilt = prebuilt or build_replay_graph(scale, rows, cols)
    for policy in POLICIES:
        stats, g, part, directions = simulate_zones(
            scale, rows, cols, policy=policy, prebuilt=prebuilt
        )
        policy_levels[policy] = directions
        zones = stats.per_phase_fmt()

        def add_row(zone, fmt, b, raw, plan="alltoall"):
            red = 100.0 * (1 - b / raw) if raw else 0.0
            speedup = pol.modeled_speedup(
                max(raw / 4, 1), ratio=max(raw / max(b, 1), 1.0)
            )
            table.append(
                {
                    "policy": policy,
                    "zone": zone,
                    "format": fmt,
                    "plan": plan,
                    "bytes": b,
                    "reduction_pct": red,
                    "modeled_time_reduction_pct": 100.0 * (1 - 1 / speedup)
                    if (fmt, plan) != ("raw", "alltoall")
                    else 0.0,
                }
            )

        for zone in ZONES:
            fmts = zones[zone]
            raw = fmts["raw"]
            for fmt in FORMATS:
                add_row(zone, fmt, fmts[fmt], raw)
        # the butterfly plan re-compresses per stage; only the row phase
        # differs from the direct plan (column/broadcast zones are shared)
        add_row(
            "rowCommunication",
            "packed",
            sum(d["row_bytes_btfly"] for d in directions),
            zones["rowCommunication"]["raw"],
            plan="btfly",
        )
    return table, policy_levels


def print_table(table: list[dict]) -> None:
    print("policy,zone,format,plan,bytes,data_reduction_pct,modeled_time_reduction_pct")
    for r in table:
        print(f"{r['policy']},{r['zone']},{r['format']},{r['plan']},{r['bytes']},"
              f"{r['reduction_pct']:.2f},{r['modeled_time_reduction_pct']:.2f}")


def print_batch(batch: dict) -> None:
    print(f"# multi-source batch (B={batch['B']}): packed-wire bytes per "
          "source vs the single-source total of the same model")
    print("policy,plan,batch,total_bytes,bytes_per_source,b1_total_bytes")
    for policy, entry in batch["policies"].items():
        for plan, d in entry["plans"].items():
            print(f"{policy},{plan},{d['batch']},{d['total_bytes']},"
                  f"{d['bytes_per_source']:.1f},{d['b1_total_bytes']}")


def print_levels(policy_levels: dict[str, list[dict]]) -> None:
    print("# per-level direction + packed row bytes (direct and butterfly)")
    print("policy,level,direction,frontier,density,row_bytes_packed,row_bytes_btfly")
    for policy, directions in policy_levels.items():
        for d in directions:
            print(f"{policy},{d['level']},{d['direction']},{d['frontier']},"
                  f"{d['density']:.4f},{d['row_bytes_packed']},{d['row_bytes_btfly']}")


def main() -> None:
    table, policy_levels = run()
    print_table(table)
    print_levels(policy_levels)


if __name__ == "__main__":
    main()
