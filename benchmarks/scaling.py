"""Paper Fig 7.1/7.2: strong and weak scaling of the distributed BFS.

Real multi-rank executions on forced host devices (subprocess per grid
size so each gets its own device count), comparing Baseline (raw) vs
compressed ('auto') — the paper's three-scenario scaling study at reduced
scale.  Reports time per BFS and TEPS.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
import os, sys, time, json
import numpy as np
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={int(sys.argv[1])*int(sys.argv[2])}"
import jax, jax.numpy as jnp
from repro.core import csr as csrmod, distributed_bfs as dbfs, validate
from repro.graphgen import builder, kronecker

rows, cols, scale, mode = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]), sys.argv[4]
g = builder.build_csr(kronecker.kronecker_edges(scale, seed=3), n=1 << scale)
mesh = jax.make_mesh((rows, cols), ("data", "model"))
bg = csrmod.partition_2d(g, rows=rows, cols=cols)
cfg = dbfs.DistBFSConfig(mode=mode)
fn = dbfs.build_bfs(mesh, bg, cfg)
src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
root = int(np.argmax(g.degrees()))
parent, level, depth = fn(src_l, dst_l, jnp.int32(root))  # compile+run
jax.block_until_ready(parent)
t0 = time.perf_counter()
reps = 3
for _ in range(reps):
    parent, level, depth = fn(src_l, dst_l, jnp.int32(root))
    jax.block_until_ready(parent)
dt = (time.perf_counter() - t0) / reps
te = validate.traversed_edges(g, np.asarray(parent)[: g.n])
print(json.dumps({"rows": rows, "cols": cols, "scale": scale, "mode": mode,
                  "time_s": dt, "teps": te / dt, "depth": int(depth)}))
"""


def _run_worker(rows: int, cols: int, scale: int, mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, str(rows), str(cols), str(scale), mode],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(strong_scale: int = 13, weak_base_scale: int = 11) -> list[dict]:
    rows = []
    # strong scaling: fixed problem, growing grid
    for r, c in ((1, 1), (2, 2), (2, 4)):
        for mode in ("raw", "auto"):
            rec = _run_worker(r, c, strong_scale, mode)
            rec["study"] = "strong"
            rows.append(rec)
    # weak scaling: problem grows with the grid (scale+2 per 4x ranks)
    for (r, c), sc in (((1, 1), weak_base_scale), ((2, 2), weak_base_scale + 2)):
        for mode in ("raw", "auto"):
            rec = _run_worker(r, c, sc, mode)
            rec["study"] = "weak"
            rows.append(rec)
    return rows


def main() -> None:
    print("study,grid,scale,mode,time_s,TEPS,depth")
    for r in run():
        print(f"{r['study']},{r['rows']}x{r['cols']},{r['scale']},{r['mode']},"
              f"{r['time_s']:.4f},{r['teps']:.3e},{r['depth']}")


if __name__ == "__main__":
    main()
