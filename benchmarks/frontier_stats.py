"""Paper Fig 5.2 / Table 5.3: statistical profile of the transmitted data.

Reproduces the paper's analysis of extracted frontier buffers: distribution
shape (uniform, slight skew), empirical entropy of ids and of gaps, and the
per-level frontier density that drives the representation buckets — plus
the traversal direction the density oracle would pick for each level
(paper §3.1: the same statistic drives wire choice AND push/pull choice).
"""

from __future__ import annotations

import numpy as np

from repro.core import bfs as bfsmod
from repro.core import traversal
from repro.graphgen import builder, kronecker, zipf


def run(scale: int = 14, seed: int = 1) -> dict:
    import jax.numpy as jnp

    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    res, sizes = bfsmod.bfs_levels(
        jnp.asarray(g.src), jnp.asarray(g.dst), jnp.int32(0), g.n, max_levels=32
    )
    lv = np.asarray(res.level)
    sizes = np.asarray(sizes)
    out = {"scale": scale, "n": g.n, "m": g.m, "levels": []}
    from repro.comm import codecs

    oracle = traversal.DensityOracle(g.n)
    use_bu = False
    for level in range(int(res.n_levels)):
        ids = np.nonzero(lv == level + 1)[0].astype(np.uint32)
        use_bu = bool(oracle.next_direction(np.int32(ids.size), use_bu))
        if ids.size < 2:
            continue
        gaps = codecs.delta_encode(ids)
        mean = ids.mean()
        std = ids.std()
        skew = float(((ids - mean) ** 3).mean() / (std**3 + 1e-12))
        out["levels"].append(
            {
                "level": level + 1,
                "count": int(ids.size),
                "density": ids.size / g.n,
                "direction": "bottom_up" if use_bu else "top_down",
                "id_entropy_bits": zipf.empirical_entropy_bits(ids),
                "gap_entropy_bits": zipf.empirical_entropy_bits(gaps),
                "mean_gap": float(gaps[1:].mean()) if gaps.size > 1 else 0.0,
                "max_gap": int(gaps.max()),
                "skewness": skew,
            }
        )
    return out


def main() -> None:
    r = run()
    print(f"# scale={r['scale']} n={r['n']} m={r['m']}")
    print("level,count,density,direction,id_H_bits,gap_H_bits,mean_gap,max_gap,skewness")
    for lv in r["levels"]:
        print(f"{lv['level']},{lv['count']},{lv['density']:.4f},{lv['direction']},"
              f"{lv['id_entropy_bits']:.2f},{lv['gap_entropy_bits']:.2f},"
              f"{lv['mean_gap']:.1f},{lv['max_gap']},{lv['skewness']:.4f}")


if __name__ == "__main__":
    main()
