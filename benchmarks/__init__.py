"""Benchmark harness: one module per paper table/figure.

| module            | paper anchor                                   |
|-------------------|------------------------------------------------|
| codecs            | Tables 5.4 / 5.5 (codec ratio + C/D speed)     |
| frontier_stats    | Fig 5.2 / Table 5.3 (frontier distribution)    |
| bfs_comm          | Tables 7.4 / 7.5 (per-zone volume + time)      |
| scaling           | Fig 7.1 / 7.2 (strong / weak scaling)          |
| breakdown         | Fig 7.3 (per-zone time breakdown)              |
| teps              | §2.6.3 (TEPS, 64-root harmonic mean)           |

``python -m benchmarks.run`` executes reduced-size versions of all of them
(scaling via ``--full``: it spawns multi-device subprocesses).
"""
