"""Paper Fig 7.3: per-zone time breakdown of one BFS iteration.

Host-instrumented replay (the Score-P analog): times each zone of the 2D
algorithm separately on real data — local SpMV, column pack/unpack, row
pack/unpack — and reports the share of wire bytes per zone from
benchmarks.bfs_comm.  Wire *time* on real hardware is modeled via the
threshold-policy link model (CPU wall clock would be meaningless for ICI).
"""

from __future__ import annotations

import time

import numpy as np


def run(scale: int = 13, rows: int = 2, cols: int = 2):
    import jax
    import jax.numpy as jnp

    from repro.comm import formats as cc
    from repro.core import csr as csrmod, validate
    from repro.graphgen import builder, kronecker
    from repro.kernels.bitpack import ops as bp

    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=3), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    part = bg.part
    s = part.chunk
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    frontier = np.nonzero(level == 2)[0]
    owner0 = frontier[frontier < s].astype(np.int32)

    ids = jnp.zeros((s,), jnp.int32).at[: owner0.size].set(jnp.asarray(owner0))
    count = jnp.int32(owner0.size)
    spec = cc.IdStreamSpec(cap=min(s, 1 << 16))  # the packed wire format

    zones = {}

    def bench(name, fn, *args):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(*args))
        zones[name] = (time.perf_counter() - t0) / 10

    # local SpMV (one block)
    src_l = jnp.asarray(bg.src_local[0, 0])
    dst_l = jnp.asarray(bg.dst_local[0, 0])
    f_col = jnp.zeros((part.n_c,), bool).at[jnp.asarray(owner0)].set(True)

    @jax.jit
    def spmv(f_col, src_l, dst_l):
        act = f_col[jnp.clip(src_l, 0, part.n_c - 1)] & (src_l < part.n_c)
        cand = jnp.where(act, src_l, np.iinfo(np.int32).max)
        return jax.ops.segment_min(cand, dst_l, num_segments=part.n_r + 1)[: part.n_r]

    bench("localExpansion(SpMV)", spmv, f_col, src_l, dst_l)

    # pull direction (bottom-up local expansion): only unreached
    # destinations accumulate; probes go through the packed bitmaps
    un = jnp.ones((part.n_r,), bool)

    @jax.jit
    def spmv_pull(f_col, un, src_l, dst_l):
        act = (
            f_col[jnp.clip(src_l, 0, part.n_c - 1)] & (src_l < part.n_c)
            & un[jnp.clip(dst_l, 0, part.n_r - 1)] & (dst_l < part.n_r)
        )
        cand = jnp.where(act, src_l, np.iinfo(np.int32).max)
        return jax.ops.segment_min(cand, dst_l, num_segments=part.n_r + 1)[: part.n_r]

    bench("localExpansion(pull)", spmv_pull, f_col, un, src_l, dst_l)

    if spec is not None:
        pack = jax.jit(lambda i, c: cc.pack_id_stream(i, c, spec))
        words, meta = pack(ids, count)
        bench("columnPack(delta+PFOR16)", pack, ids, count)
        unpack = jax.jit(lambda w, m: cc.unpack_id_stream(w, m, spec, fill=s))
        bench("columnUnpack(+cumsum)", unpack, words, meta)
    bench("bitmapPack", jax.jit(cc.pack_bitmap), f_col[:s])
    bench("frontierCompact", jax.jit(lambda b: bp.compact_ids(b, s, s)), f_col[:s])
    return zones


#: pure-ELL slab budget: hub blocks whose container would exceed this are
#: recorded as skipped (the exact affordability cliff the hybrid split is
#: for), not silently built
ELL_SLAB_BUDGET_BYTES = 1 << 28


def expansion_breakdown(
    scale: int = 15, rows: int = 2, cols: int = 2, repeats: int = 5
) -> dict:
    """Per-level local-expansion wall time for each backend (coo/ell/hybrid).

    Replays the hub-root BFS level by level on block (0, 0) of the 2D
    partition and times each backend's *push* and *pull* expansion of the
    real frontier — the compute half of the level the wire plans wrap.
    Backend choice is compute-local, so this is the one benchmark axis the
    CommStats byte tables cannot see.  A pure-ELL container whose slab
    would blow :data:`ELL_SLAB_BUDGET_BYTES` (hub rows force the width) is
    recorded as skipped with the offending size — the affordability cliff
    that motivates the hybrid split.  Emitted into BENCH_comm.json as the
    ``compute`` section.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import csr as csrmod, expand as expand_mod, validate
    from repro.graphgen import builder, kronecker

    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=1), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    part = bg.part
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    level_pad = np.full(part.n, -1, level.dtype)
    level_pad[: g.n] = level
    max_level = int(level.max())
    src_l = jnp.asarray(bg.src_local[0, 0])
    dst_l = jnp.asarray(bg.dst_local[0, 0])
    col_slice = level_pad[: part.n_c]  # block (0, 0) reads column slice 0
    row_slice = level_pad[: part.n_r]

    out = {
        "scale": scale, "rows": rows, "cols": cols, "block": [0, 0],
        "root": root, "backends": {},
    }
    for name in expand_mod.BACKENDS:
        backend = expand_mod.resolve(name)
        if name == "ell":
            # the exact width ell_blocked would allocate (max over ALL
            # blocks — the hub may live in any row slice)
            k = csrmod.ell_slab_width(bg)
            slab_bytes = rows * cols * part.n_r * k * 4
            if slab_bytes > ELL_SLAB_BUDGET_BYTES:
                out["backends"][name] = {
                    "skipped": f"pure-ELL slab would be {slab_bytes} bytes "
                    f"(k={k} from the hub rows) — the cliff hybrid avoids",
                    "slab_bytes": slab_bytes,
                }
                continue
        extra = tuple(
            jnp.asarray(a[0, 0]) for a in backend.block_arrays(bg)
        )
        block = backend.local_block(src_l, dst_l, extra, part.n_r, part.n_c)
        push = jax.jit(lambda f, _b=backend, _blk=block: _b.push_planes(_blk, f))
        pull = jax.jit(
            lambda f, u, _b=backend, _blk=block: _b.pull_planes(_blk, f, u)
        )
        levels = []
        for lv in range(max_level):
            f_col = jnp.asarray(col_slice == lv)[None]
            un = jnp.asarray((row_slice > lv) | (row_slice < 0))[None]
            jax.block_until_ready(push(f_col))  # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(push(f_col))
            push_us = (time.perf_counter() - t0) / repeats * 1e6
            jax.block_until_ready(pull(f_col, un))
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(pull(f_col, un))
            pull_us = (time.perf_counter() - t0) / repeats * 1e6
            levels.append(
                {"level": lv, "frontier": int(np.sum(col_slice == lv)),
                 "push_us": push_us, "pull_us": pull_us}
            )
        entry = {"levels": levels}
        info = backend.describe(bg)
        if info:
            entry["split_k"] = info[0]["split_k"]
            entry["padding_ratio"] = info[0]["padding_ratio"]
        out["backends"][name] = entry
    return out


def print_expansion(compute: dict) -> None:
    print("# local expansion per level, block (0,0): wall us per call")
    print("backend,level,frontier,push_us,pull_us")
    for name, entry in compute["backends"].items():
        if "skipped" in entry:
            print(f"{name},skipped,,{entry['skipped']!r},")
            continue
        for d in entry["levels"]:
            print(f"{name},{d['level']},{d['frontier']},"
                  f"{d['push_us']:.1f},{d['pull_us']:.1f}")


def main_zones() -> None:
    print("zone,host_us_per_call")
    for k, v in run().items():
        print(f"{k},{v * 1e6:.1f}")


def main() -> None:
    main_zones()
    print_expansion(expansion_breakdown())


if __name__ == "__main__":
    main()
