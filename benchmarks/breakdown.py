"""Paper Fig 7.3: per-zone time breakdown of one BFS iteration.

Host-instrumented replay (the Score-P analog): times each zone of the 2D
algorithm separately on real data — local SpMV, column pack/unpack, row
pack/unpack — and reports the share of wire bytes per zone from
benchmarks.bfs_comm.  Wire *time* on real hardware is modeled via the
threshold-policy link model (CPU wall clock would be meaningless for ICI).
"""

from __future__ import annotations

import time

import numpy as np


def run(scale: int = 13, rows: int = 2, cols: int = 2):
    import jax
    import jax.numpy as jnp

    from repro.compression import collectives as cc
    from repro.core import csr as csrmod, validate
    from repro.graphgen import builder, kronecker
    from repro.kernels.bitpack import ops as bp

    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=3), n=1 << scale)
    bg = csrmod.partition_2d(g, rows=rows, cols=cols)
    part = bg.part
    s = part.chunk
    root = int(np.argmax(g.degrees()))
    level = validate.reference_bfs(g, root)
    frontier = np.nonzero(level == 2)[0]
    owner0 = frontier[frontier < s].astype(np.int32)

    ids = jnp.zeros((s,), jnp.int32).at[: owner0.size].set(jnp.asarray(owner0))
    count = jnp.int32(owner0.size)
    spec = cc.IdStreamSpec(cap=min(s, 1 << 16))  # the packed wire format

    zones = {}

    def bench(name, fn, *args):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(*args))
        zones[name] = (time.perf_counter() - t0) / 10

    # local SpMV (one block)
    src_l = jnp.asarray(bg.src_local[0, 0])
    dst_l = jnp.asarray(bg.dst_local[0, 0])
    f_col = jnp.zeros((part.n_c,), bool).at[jnp.asarray(owner0)].set(True)

    @jax.jit
    def spmv(f_col, src_l, dst_l):
        act = f_col[jnp.clip(src_l, 0, part.n_c - 1)] & (src_l < part.n_c)
        cand = jnp.where(act, src_l, np.iinfo(np.int32).max)
        return jax.ops.segment_min(cand, dst_l, num_segments=part.n_r + 1)[: part.n_r]

    bench("localExpansion(SpMV)", spmv, f_col, src_l, dst_l)

    # pull direction (bottom-up local expansion): only unreached
    # destinations accumulate; probes go through the packed bitmaps
    un = jnp.ones((part.n_r,), bool)

    @jax.jit
    def spmv_pull(f_col, un, src_l, dst_l):
        act = (
            f_col[jnp.clip(src_l, 0, part.n_c - 1)] & (src_l < part.n_c)
            & un[jnp.clip(dst_l, 0, part.n_r - 1)] & (dst_l < part.n_r)
        )
        cand = jnp.where(act, src_l, np.iinfo(np.int32).max)
        return jax.ops.segment_min(cand, dst_l, num_segments=part.n_r + 1)[: part.n_r]

    bench("localExpansion(pull)", spmv_pull, f_col, un, src_l, dst_l)

    if spec is not None:
        pack = jax.jit(lambda i, c: cc.pack_id_stream(i, c, spec))
        words, meta = pack(ids, count)
        bench("columnPack(delta+PFOR16)", pack, ids, count)
        unpack = jax.jit(lambda w, m: cc.unpack_id_stream(w, m, spec, fill=s))
        bench("columnUnpack(+cumsum)", unpack, words, meta)
    bench("bitmapPack", jax.jit(cc.pack_bitmap), f_col[:s])
    bench("frontierCompact", jax.jit(lambda b: bp.compact_ids(b, s, s)), f_col[:s])
    return zones


def main() -> None:
    print("zone,host_us_per_call")
    for k, v in run().items():
        print(f"{k},{v * 1e6:.1f}")


if __name__ == "__main__":
    main()
