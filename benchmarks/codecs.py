"""Paper Tables 5.4/5.5: codec comparison (ratio, bits/int, C/D speed).

Two data sets, as in the paper:
* a real frontier-queue buffer extracted from a BFS run on an RMAT graph
  (Table 5.4 analog; the paper measured uniform-slightly-skewed, ~15-bit
  entropy) and
* a Zipf-skewed inverted-index-like stream (Table 5.5 / TREC-GOV2 analog).
"""

from __future__ import annotations

import time

import numpy as np

from repro.comm import registry
from repro.comm import codecs
from repro.core import bfs as bfsmod
from repro.graphgen import builder, kronecker, zipf


def extract_frontier_stream(scale: int = 14, level: int = 3, seed: int = 1) -> np.ndarray:
    """Run a real BFS and extract the sorted vertex ids of one frontier."""
    import jax.numpy as jnp

    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=seed), n=1 << scale)
    res = bfsmod.bfs(jnp.asarray(g.src), jnp.asarray(g.dst), jnp.int32(0), g.n)
    lv = np.asarray(res.level)
    ids = np.nonzero(lv == level)[0].astype(np.uint32)
    return ids


def bench_codec(codec: codecs.Codec, values: np.ndarray, repeat: int = 3):
    blob = codec.encode(values)
    t0 = time.perf_counter()
    for _ in range(repeat):
        codec.encode(values)
    enc_s = (time.perf_counter() - t0) / repeat
    t0 = time.perf_counter()
    for _ in range(repeat):
        codec.decode(blob, values.size)
    dec_s = (time.perf_counter() - t0) / repeat
    bits_per_int = len(blob) * 8 / values.size
    return {
        "codec": codec.name,
        "ratio_pct": 100.0 * len(blob) / (values.size * 4),
        "bits_per_int": bits_per_int,
        "c_speed_mis": values.size / enc_s / 1e6,
        "d_speed_mis": values.size / dec_s / 1e6,
    }


def run(scale: int = 14, n_zipf: int = 200_000) -> list[dict]:
    rows = []
    frontier = extract_frontier_stream(scale=scale)
    gaps = codecs.delta_encode(frontier)
    h = zipf.empirical_entropy_bits(gaps)
    rows.append({"codec": f"H(x)_gaps={h:.2f}bit", "dataset": "frontier"})
    for name in registry.available_codecs():
        c = registry.make_codec(name)
        if name == "bitmap" and frontier.size == 0:
            continue
        r = bench_codec(c, frontier)
        r["dataset"] = "frontier"
        rows.append(r)
    stream = np.sort(np.unique(zipf.zipf_stream(n_zipf, alpha=1.2, seed=0)))
    for name in registry.available_codecs():
        c = registry.make_codec(name)
        r = bench_codec(c, stream.astype(np.uint32))
        r["dataset"] = "zipf-index"
        rows.append(r)
    return rows


def main() -> None:
    print("codec,dataset,ratio_pct,bits_per_int,c_speed_MI/s,d_speed_MI/s")
    for r in run():
        if "ratio_pct" in r:
            print(f"{r['codec']},{r['dataset']},{r['ratio_pct']:.2f},"
                  f"{r['bits_per_int']:.2f},{r['c_speed_mis']:.1f},{r['d_speed_mis']:.1f}")
        else:
            print(f"{r['codec']},{r['dataset']},,,,")


if __name__ == "__main__":
    main()
