"""Repo-wide pytest setup.

Property tests use Hypothesis when it is installed.  On air-gapped images
where it is not, fall back to the tiny deterministic shim in
``tests/_shims/hypothesis`` (same decorator API, seeded random sampling)
so the suite still collects and the properties still get exercised.
"""

import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests", "_shims"))
