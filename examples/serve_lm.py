"""Serve a small LM with batched requests (slot-based continuous batching).

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.serve import engine as eng


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = tfm.TransformerConfig(
        name="serve-demo", n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=1024, vocab=4096, q_chunk=64, kv_chunk=64,
        compute_dtype=jnp.float32,
    )
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    engine = eng.Engine(
        cfg, params, batch_slots=args.slots, max_seq=128,
        temperature=args.temperature,
    )
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 24))
        req = eng.Request(rid=i, prompt=prompt.astype(np.int32), max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.perf_counter()
    ticks = 0
    while any(not r.done for r in reqs):
        engine.tick()
        ticks += 1
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, {ticks} engine ticks, "
          f"{args.slots} slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
