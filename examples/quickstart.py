"""Quickstart: the paper's pipeline in 30 lines.

Generate a Graph500 Kronecker graph, run the SpMV-formulated BFS, validate
the tree, and show what the compression layer does to the frontier stream.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.comm import registry
from repro.core import bfs, validate
from repro.graphgen import builder, kronecker

SCALE = 12

print(f"1. generating Kronecker graph, scale={SCALE}, edgefactor=16 ...")
edges = kronecker.kronecker_edges(SCALE, seed=1)
g = builder.build_csr(edges, n=1 << SCALE)
print(f"   n={g.n:,} vertices, m={g.m:,} symmetric edges")

root = int(np.argmax(g.degrees()))
print(f"2. BFS from root {root} (edge-centric SpMV, lax.while_loop) ...")
res = bfs.bfs(jnp.asarray(g.src), jnp.asarray(g.dst), jnp.int32(root), g.n)
print(f"   {int((np.asarray(res.level) >= 0).sum()):,} vertices reached "
      f"in {int(res.n_levels)} levels")

print("3. validating against the Graph500 5 rules ...")
v = validate.validate_bfs_tree(g, np.asarray(res.parent), root, np.asarray(res.level))
print(f"   valid={v.ok} tree_edges={v.n_tree_edges:,}")

print("4. compressing one frontier (the paper's contribution) ...")
ids = np.nonzero(np.asarray(res.level) == 2)[0].astype(np.uint32)
raw = ids.size * 4
for name in ("copy", "vbyte-delta", "bp128d"):
    codec = registry.make_codec(name)
    blob = codec.encode(ids)
    assert np.array_equal(codec.decode(blob, ids.size), ids)
    print(f"   {name:12s}: {raw:8,d} B -> {len(blob):8,d} B "
          f"({100 * (1 - len(blob) / raw):5.1f}% reduction)")
