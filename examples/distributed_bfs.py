"""Distributed 2D-partitioned BFS with compressed collectives (paper Alg. 4).

Runs on forced host devices so the full column/row collective pipeline
(TransposeVector ppermute -> compressed all-gather -> SpMV -> compressed
all-to-all) executes for real, and compares the three wire formats.

    PYTHONPATH=src python examples/distributed_bfs.py --grid 2x2 --scale 12
"""

import argparse
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--grid", default="2x2")
ap.add_argument("--scale", type=int, default=12)
ap.add_argument("--policy", default="top_down",
                choices=["top_down", "bottom_up", "direction_opt"],
                help="traversal direction policy (paper §3.1)")
args = ap.parse_args()
ROWS, COLS = (int(x) for x in args.grid.split("x"))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ROWS * COLS}"
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import csr as csrmod  # noqa: E402
from repro.core import distributed_bfs as dbfs  # noqa: E402
from repro.core import validate  # noqa: E402
from repro.graphgen import builder, kronecker  # noqa: E402


def main() -> None:
    g = builder.build_csr(kronecker.kronecker_edges(args.scale, seed=3), n=1 << args.scale)
    mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
    bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS)
    root = int(np.argmax(g.degrees()))
    print(f"grid {ROWS}x{COLS}, n={g.n:,} (padded {bg.part.n:,}), m={g.m:,}, "
          f"chunk s={bg.part.chunk:,}, e_cap={bg.e_cap:,}")

    ref = validate.reference_bfs(g, root)
    for mode in ("raw", "bitmap", "auto", "btfly"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=args.policy)
        fn = dbfs.build_bfs(mesh, bg, cfg)
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(src_l, dst_l, jnp.int32(root))
        jax.block_until_ready(parent)
        t0 = time.perf_counter()
        parent, level, depth = fn(src_l, dst_l, jnp.int32(root))
        jax.block_until_ready(parent)
        dt = time.perf_counter() - t0
        ok = np.array_equal(np.asarray(level)[: g.n], ref)
        v = validate.validate_bfs_tree(g, np.asarray(parent)[: g.n], root)
        print(f"  mode={mode:7s} policy={args.policy:13s} depth={int(depth):2d} "
              f"time={dt:.3f}s levels_match={ok} graph500_valid={v.ok}")


if __name__ == "__main__":
    main()
