"""Distributed 2D-partitioned BFS with compressed collectives (paper Alg. 4).

Runs on forced host devices so the full column/row collective pipeline
(TransposeVector ppermute -> compressed all-gather -> SpMV -> compressed
all-to-all) executes for real, and compares the four wire plans.

    PYTHONPATH=src python examples/distributed_bfs.py --grid 2x2 --scale 12

``--expand`` picks the local-expansion backend (coo / ell / hybrid; auto =
hybrid with the histogram-chosen split) and prints each block's split K
and ELL padding ratio — results are bit-identical across backends.

``--batch B`` traverses B sources at once: the frontier/parent carries
widen to (B, s) planes and every exchange moves all B planes under one
wire header and one bucket consensus.  The batched parents then feed the
betweenness-centrality accumulation from
``repro.core.centrality.tree_betweenness`` (Brandes-style dependency pass
over each source's BFS tree) — the workload family multi-source batching
opens up.
"""

import argparse
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--grid", default="2x2")
ap.add_argument("--scale", type=int, default=12)
ap.add_argument("--policy", default="top_down",
                choices=["top_down", "bottom_up", "direction_opt"],
                help="traversal direction policy (paper §3.1)")
ap.add_argument("--batch", type=int, default=1,
                help="number of BFS sources traversed simultaneously (B)")
ap.add_argument("--expand", default="coo",
                choices=["coo", "ell", "hybrid", "auto"],
                help="local-expansion backend (auto = hybrid with the "
                     "histogram-chosen split)")
args = ap.parse_args()
ROWS, COLS = (int(x) for x in args.grid.split("x"))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ROWS * COLS}"
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bfs as bfsmod  # noqa: E402
from repro.core import csr as csrmod  # noqa: E402
from repro.core import distributed_bfs as dbfs  # noqa: E402
from repro.core import expand as expand_mod  # noqa: E402
from repro.core import validate  # noqa: E402
from repro.core.centrality import tree_betweenness  # noqa: E402
from repro.graphgen import builder, kronecker  # noqa: E402


def main() -> None:
    g = builder.build_csr(kronecker.kronecker_edges(args.scale, seed=3), n=1 << args.scale)
    mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
    bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS)
    deg = g.degrees()
    # same hub-root convention as the benchmark's acceptance rows
    roots = bfsmod.hub_roots(deg, args.batch).astype(np.int32)
    root_arg = jnp.int32(int(roots[0])) if args.batch == 1 else jnp.asarray(roots)
    print(f"grid {ROWS}x{COLS}, n={g.n:,} (padded {bg.part.n:,}), m={g.m:,}, "
          f"chunk s={bg.part.chunk:,}, e_cap={bg.e_cap:,}, "
          f"batch B={args.batch} roots={roots.tolist()} expand={args.expand}")
    backend = expand_mod.resolve(args.expand)
    for d in backend.describe(bg):
        residue = (f" residue_edges={d['residue_edges']:,}"
                   if "residue_edges" in d else "")
        print(f"  block {tuple(d['block'])}: split_k={d['split_k']} "
              f"ell_padding_ratio={d['padding_ratio']:.3f}{residue}")

    refs = {int(r): validate.reference_bfs(g, int(r)) for r in roots}
    last = None
    for mode in ("raw", "bitmap", "auto", "btfly"):
        cfg = dbfs.DistBFSConfig(mode=mode, policy=args.policy,
                                 expand=args.expand)
        fn = dbfs.build_bfs(mesh, bg, cfg)
        blocks = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(*blocks, root_arg)
        jax.block_until_ready(parent)
        t0 = time.perf_counter()
        parent, level, depth = fn(*blocks, root_arg)
        jax.block_until_ready(parent)
        dt = time.perf_counter() - t0
        parent_np = np.atleast_2d(np.asarray(parent))[:, : g.n]
        level_np = np.atleast_2d(np.asarray(level))[:, : g.n]
        ok = all(
            np.array_equal(level_np[k], refs[int(r)])
            for k, r in enumerate(roots)
        )
        valid = all(
            validate.validate_bfs_tree(g, parent_np[k], int(r)).ok
            for k, r in enumerate(roots)
        )
        print(f"  mode={mode:7s} policy={args.policy:13s} depth={int(depth):2d} "
              f"time={dt:.3f}s levels_match={ok} graph500_valid={valid} "
              f"({dt / args.batch:.3f}s/source)")
        last = (parent_np, level_np)

    if args.batch > 1 and last is not None:
        parent_np, level_np = last
        bc = tree_betweenness(parent_np, level_np, g.n)
        top = np.argsort(-bc)[:5]
        print(f"\nbetweenness accumulation over {args.batch} batched sources "
              "(tree-dependency approximation):")
        for v in top:
            print(f"  vertex {int(v):>8d}  degree {int(deg[v]):>6d}  "
                  f"centrality {bc[v]:,.0f}")


if __name__ == "__main__":
    main()
