"""Train a ~100M-param LM for a few hundred steps (end-to-end driver).

Uses the minicpm-2b architecture scaled to ~100M params, the WSD schedule,
the deterministic synthetic token pipeline, async checkpointing and the
watchdog.  Kill it mid-run and rerun the same command: it resumes exactly.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --ckpt /tmp/lm_ckpt
"""

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import tokens as dtokens
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import checkpoint, fault
from repro.train import step as tstep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    cfg = tfm.TransformerConfig(
        name="lm-100m",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=8,
        head_dim=args.d_model // 8,
        d_ff=args.d_model * 4,
        vocab=16384,
        q_chunk=128,
        kv_chunk=128,
        compute_dtype=jnp.float32,
    )
    print(f"model: {cfg.n_params() / 1e6:.1f}M params")
    opt_cfg = adamw.AdamWConfig(
        lr=3e-4, warmup_steps=args.steps // 20, total_steps=args.steps
    )
    step_fn = jax.jit(tstep.make_train_step(functools.partial(tfm.loss_fn, cfg), opt_cfg))
    pipe = dtokens.TokenPipelineConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)

    def init():
        return tstep.init_state(tfm.init_params(cfg, jax.random.PRNGKey(0)))

    if args.ckpt:
        state, start = fault.resume_or_init(init, args.ckpt)
        ckpt = checkpoint.AsyncCheckpointer(args.ckpt)
        if start:
            print(f"resumed at step {start}")
    else:
        state, start, ckpt = init(), 0, None

    loader = dtokens.DoubleBufferedLoader(pipe, start_step=start)
    dog = fault.StepWatchdog()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        dog.start()
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        dog.stop()
        losses.append(loss)
        if step % 20 == 0:
            print(f"step {step:4d} loss {loss:.4f}")
        if ckpt is not None and (step + 1) % 50 == 0:
            ckpt.submit(state, step)
    loader.close()
    if ckpt is not None:
        ckpt.submit(state, args.steps - 1)
        ckpt.wait()
    print(f"loss: {np.mean(losses[:10]):.4f} -> {np.mean(losses[-10:]):.4f} "
          f"(stragglers: {len(dog.stragglers)})")


if __name__ == "__main__":
    main()
