"""End-to-end Graph500 driver (paper Algorithm 1) — the paper-kind e2e run.

Generation (untimed) -> Kernel 1: CSR construction (timed) -> 64x Kernel 2:
BFS + validation (timed) -> harmonic-mean TEPS.  Codec is selected via the
factory (paper §5.3) and the frontier bytes per level are reported.

    PYTHONPATH=src python examples/graph500_benchmark.py --scale 13 --roots 8
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.comm import registry
from repro.core import bfs, validate
from repro.graphgen import builder, kronecker


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=8, help="spec says 64")
    ap.add_argument("--codec", default="bp128d", choices=registry.available_codecs())
    args = ap.parse_args()

    print(f"# Graph500 scale={args.scale} edgefactor={args.edgefactor}")
    edges = kronecker.kronecker_edges(args.scale, args.edgefactor, seed=1)

    t0 = time.perf_counter()
    g = builder.build_csr(edges, n=1 << args.scale)
    print(f"Kernel1 (construction): {time.perf_counter() - t0:.3f}s  m={g.m:,}")

    codec = registry.make_codec(args.codec)  # factory call OUTSIDE Kernel 2
    rng = np.random.default_rng(2)
    roots = rng.choice(np.nonzero(g.degrees() > 0)[0], size=args.roots, replace=False)
    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    jax.block_until_ready(bfs.bfs(src, dst, jnp.int32(int(roots[0])), g.n).parent)

    teps, comm_raw, comm_comp = [], 0, 0
    for i, root in enumerate(roots):
        t0 = time.perf_counter()
        res = bfs.bfs(src, dst, jnp.int32(int(root)), g.n)
        jax.block_until_ready(res.parent)
        dt = time.perf_counter() - t0
        v = validate.validate_bfs_tree(g, np.asarray(res.parent), int(root),
                                       np.asarray(res.level))
        assert v.ok, v.failures
        te = validate.traversed_edges(g, np.asarray(res.parent))
        teps.append(te / dt)
        lv = np.asarray(res.level)
        for level in range(1, int(res.n_levels) + 1):
            ids = np.nonzero(lv == level)[0].astype(np.uint32)
            if ids.size:
                comm_raw += ids.size * 4
                comm_comp += len(codec.encode(ids))
        print(f"  root {int(root):8d}: {dt:.3f}s  {te / dt:.3e} TEPS  valid={v.ok}")

    hm = len(teps) / sum(1.0 / t for t in teps)
    print(f"\nTEPS harmonic mean over {args.roots} roots: {hm:.3e}")
    print(f"frontier bytes: raw={comm_raw:,} {args.codec}={comm_comp:,} "
          f"({100 * (1 - comm_comp / max(comm_raw, 1)):.1f}% reduction — paper: >90%)")


if __name__ == "__main__":
    main()
