"""End-to-end Graph500 harness (paper Algorithm 1) on the distributed driver.

Generation (untimed) -> Kernel 1: CSR construction + 2D partition (timed)
-> Kernel 2: 64 BFS searches from the spec's valid-root sample, traversed
in batches of B sources through the distributed 2D driver on forced host
devices (every column/row collective executes for real) -> per-tree
Graph500 validation -> harmonic-mean TEPS via :mod:`benchmarks.teps`.
The codec comparison of earlier revisions lives on in the frontier-bytes
report: per-level frontier ids are priced raw vs compressed.

    PYTHONPATH=src python examples/graph500_benchmark.py --grid 2x2 --scale 13

64 roots is the spec's count; ``--roots 8`` keeps CPU smoke runs short.
With ``--batch B`` each timed kernel traverses B sources at once, so the
per-source time is dt/B — the TEPS statistic stays per-search, as the
spec defines it.
"""

import argparse
import os
import sys

# the TEPS helpers live in the top-level benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

ap = argparse.ArgumentParser()
ap.add_argument("--grid", default="2x2")
ap.add_argument("--scale", type=int, default=13)
ap.add_argument("--edgefactor", type=int, default=16)
ap.add_argument("--roots", type=int, default=64, help="spec says 64")
ap.add_argument("--batch", type=int, default=8,
                help="sources traversed per timed kernel (B planes)")
ap.add_argument("--mode", default="auto",
                choices=["raw", "bitmap", "auto", "btfly"])
ap.add_argument("--policy", default="direction_opt",
                choices=["top_down", "bottom_up", "direction_opt"])
ap.add_argument("--expand", default="hybrid",
                choices=["coo", "ell", "hybrid", "auto"])
ap.add_argument("--codec", default="bp128d")
ap.add_argument("--no-validate", action="store_true",
                help="skip the per-tree Graph500 5-rule validation")
args = ap.parse_args()
ROWS, COLS = (int(x) for x in args.grid.split("x"))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ROWS * COLS}"
)

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks import teps  # noqa: E402
from repro.comm import registry  # noqa: E402
from repro.core import csr as csrmod  # noqa: E402
from repro.core import distributed_bfs as dbfs  # noqa: E402
from repro.core import validate  # noqa: E402
from repro.graphgen import builder, kronecker  # noqa: E402


def main() -> None:
    print(f"# Graph500 scale={args.scale} edgefactor={args.edgefactor} "
          f"grid={ROWS}x{COLS} batch={args.batch} mode={args.mode} "
          f"policy={args.policy} expand={args.expand}")
    edges = kronecker.kronecker_edges(args.scale, args.edgefactor, seed=1)

    t0 = time.perf_counter()
    g = builder.build_csr(edges, n=1 << args.scale)
    bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS)
    print(f"Kernel1 (construction + 2D partition): "
          f"{time.perf_counter() - t0:.3f}s  m={g.m:,}  "
          f"chunk s={bg.part.chunk:,}  e_cap={bg.e_cap:,}")

    if args.roots % args.batch:
        raise SystemExit(f"--roots {args.roots} must be a multiple of "
                         f"--batch {args.batch}")
    roots = teps.valid_roots(g, args.roots, seed=2)

    mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
    cfg = dbfs.DistBFSConfig(mode=args.mode, policy=args.policy,
                             expand=args.expand)
    fn = dbfs.build_bfs(mesh, bg, cfg)
    blocks = dbfs.shard_blocked(mesh, bg, cfg)
    codec = registry.make_codec(args.codec)  # factory call OUTSIDE Kernel 2

    # warm-up compile (untimed, like the spec's untimed setup)
    warm = roots[: args.batch]
    jax.block_until_ready(fn(*blocks, jnp.asarray(warm))[0])

    teps_list, comm_raw, comm_comp = [], 0, 0
    for lo in range(0, args.roots, args.batch):
        chunk = roots[lo : lo + args.batch]
        t0 = time.perf_counter()
        parent, level, depth = fn(*blocks, jnp.asarray(chunk))
        jax.block_until_ready(parent)
        dt = time.perf_counter() - t0
        parent_np = np.asarray(parent)[:, : g.n]
        level_np = np.asarray(level)[:, : g.n]
        per_source = dt / args.batch
        for k, root in enumerate(chunk):
            te = validate.traversed_edges(g, parent_np[k])
            if not args.no_validate:
                v = validate.validate_bfs_tree(g, parent_np[k], int(root),
                                               level_np[k])
                assert v.ok, (int(root), v.failures)
            teps_list.append(te / per_source)
            lv = level_np[k]
            for d in range(1, int(depth) + 1):
                ids = np.nonzero(lv == d)[0].astype(np.uint32)
                if ids.size:
                    comm_raw += ids.size * 4
                    comm_comp += len(codec.encode(ids))
        print(f"  roots[{lo}:{lo + args.batch}]: {dt:.3f}s "
              f"({per_source:.3f}s/source)  depth={int(depth)}  "
              f"min TEPS {min(teps_list[lo:]):.3e}")

    hm = teps.harmonic_mean(teps_list)
    print(f"\nTEPS harmonic mean over {args.roots} roots "
          f"(batch {args.batch}): {hm:.3e}")
    print(f"frontier bytes: raw={comm_raw:,} {args.codec}={comm_comp:,} "
          f"({100 * (1 - comm_comp / max(comm_raw, 1)):.1f}% reduction — "
          f"paper: >90%)")


if __name__ == "__main__":
    main()
