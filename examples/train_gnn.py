"""Train GraphCast-style interaction networks on the icosahedral multimesh.

Builds the refinement-r multimesh (the real GraphCast processor graph),
attaches synthetic "weather state" node features, and regresses next-state
targets — the encode-process-decode pipeline end to end.

    PYTHONPATH=src python examples/train_gnn.py --refine 3 --steps 50
"""

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import gnn, icosahedron
from repro.optim import adamw
from repro.train import step as tstep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--refine", type=int, default=3)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--d-hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--n-vars", type=int, default=16)
    args = ap.parse_args()

    verts, edges = icosahedron.multimesh(args.refine)
    n, m = verts.shape[0], edges.shape[0]
    print(f"multimesh refine={args.refine}: {n:,} nodes, {m:,} directed edges")

    cfg = gnn.GraphCastConfig(
        n_layers=args.layers, d_hidden=args.d_hidden,
        d_in=args.n_vars, d_out=args.n_vars, mesh_refinement=args.refine,
    )
    rng = np.random.default_rng(0)
    # synthetic smooth field: value = f(position) + noise; target = advected
    base = np.stack([verts @ rng.normal(size=3) for _ in range(args.n_vars)], 1)
    nf = (base + 0.1 * rng.normal(size=(n, args.n_vars))).astype(np.float32)
    targets = np.roll(base, 1, axis=1).astype(np.float32)

    g = gnn.Graph(
        nf=jnp.asarray(nf),
        src=jnp.asarray(edges[:, 0], dtype=jnp.int32),
        dst=jnp.asarray(edges[:, 1], dtype=jnp.int32),
        pos=jnp.asarray(verts, dtype=jnp.float32),
    )
    batch = {"graph": g, "targets": jnp.asarray(targets)}

    params = gnn.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)
    step_fn = jax.jit(
        tstep.make_train_step(functools.partial(gnn.loss_fn, cfg), opt_cfg)
    )
    state = tstep.init_state(params)
    first = last = None
    for step in range(args.steps):
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        first = loss if first is None else first
        last = loss
        if step % 10 == 0:
            print(f"step {step:3d} loss {loss:.5f}")
    print(f"loss {first:.5f} -> {last:.5f} ({'improved' if last < first else 'FAILED'})")


if __name__ == "__main__":
    main()
