"""Verify BENCH_comm.json's staged butterfly volumes against the static
byte model, and the multi-source batch acceptance invariant.

Usage: PYTHONPATH=src python scripts/check_bench_comm.py [BENCH_comm.json]

Every ``btfly_stages`` entry the host replay logged must satisfy

    bytes == senders * subchunks * stage_unit_bytes(s, n, fmt, b=batch)

up to one packing chunk of padding per subchunk-plane — the stage formats
are static-geometry wire formats, so any larger disagreement means the
replay and the device wire plan have diverged (the exact contamination the
butterfly-vs-alltoall comparison must not carry).  Also re-checks that the
per-level ``row_bytes_btfly`` totals equal the sum of their stages and that
the table's btfly row equals the per-level sum.

The ``batch`` section adds the multi-source invariant: ``bytes_per_source``
at B>1 must sit strictly below the B=1 total of the same packed-wire model
for both row-phase plans on the policies whose wire actually amortizes
(top_down's id-stream headers; direction_opt's shared degree psum + mixed
wires).  Pure bottom_up's direct pull wire is density-independent per
plane — every component scales linearly — so it is held to non-strict
(no regression) instead.
"""

from __future__ import annotations

import json
import sys

from repro.comm import butterfly

#: slack per subchunk-plane: one 1024-value packing chunk of u32 words
PAD_BYTES = 4 * 1024

#: policies whose batched packed wire must be STRICTLY cheaper per source
STRICT_BATCH_POLICIES = ("top_down", "direction_opt")


def value_unit_bytes(fmt: str, collective: str, s: int, r: int, c: int) -> int:
    """Static per-plane wire bytes of a value-payload record (the frontier-
    algebra axis: SSSP distances, CC labels, PageRank mass).

    ``values``: the per-level value gather — the transpose ppermute moves
    one owned chunk (s int32 words); the column all-gather replicates it
    across the r grid rows (r*s words, per-device result-shape
    convention).  ``dense-i32``: the dense int32 row combine ships one
    chunk per row peer (all-to-all over c columns) or one chunk per
    butterfly stage (ppermute).  Both are density-independent, so the
    model is exact — any disagreement with a replayed ledger is drift.
    """
    if fmt == "values":
        return 4 * (r * s if collective == "all-gather" else s)
    if fmt == "dense-i32":
        return 4 * (c * s if collective == "all-to-all" else s)
    raise KeyError(f"not a value-payload format: {fmt}")


def check_value_records(records, s: int, r: int, c: int) -> int:
    """Price every value-payload record of a CommStats ledger against the
    static model.  Exits non-zero on any drift; returns entries checked."""
    n_checked = 0
    for rec in records:
        if rec.fmt not in ("values", "dense-i32"):
            continue
        model = value_unit_bytes(rec.fmt, rec.collective, s, r, c) * rec.count
        if rec.nbytes != model:
            raise SystemExit(
                f"{rec.phase}: {rec.fmt} {rec.collective} ledger {rec.nbytes} B "
                f"vs static model {model} B (s={s}, r={r}, c={c})"
            )
        n_checked += 1
    return n_checked


def _check_stage(e: dict, s: int, n: int, ctx: str = "") -> None:
    zone = e.get("zone", "row")
    if zone == "row-pull":
        zone = "row"  # the pull butterfly rides the same row wire
    b = e.get("batch", 1)
    unit = butterfly.stage_unit_bytes(s, n, e["fmt"], zone=zone, b=b)
    model = e["senders"] * e["subchunks"] * unit
    tol = e["senders"] * e["subchunks"] * b * PAD_BYTES
    if abs(e["bytes"] - model) > tol:
        where = " ".join(
            [ctx] + [f"{k}={e[k]}" for k in ("grid_row", "level", "zone")
                     if k in e]
        ).strip()
        raise SystemExit(
            f"{where} stage {e['stage']}: replayed {e['bytes']} B vs model "
            f"{model} B (fmt={e['fmt']}, batch={b}, tol={tol})"
        )


def check(doc: dict) -> int:
    s, n = doc["chunk"], doc["n"]
    n_checked = 0
    for policy, levels in doc["policy_levels"].items():
        total = 0
        for d in levels:
            level_sum = 0
            for e in d["btfly_stages"]:
                _check_stage(e, s, n, ctx=f"{policy} level {d['level']}")
                level_sum += e["bytes"]
                n_checked += 1
            if level_sum != d["row_bytes_btfly"]:
                raise SystemExit(
                    f"{policy} level {d['level']}: stage sum {level_sum} != "
                    f"row_bytes_btfly {d['row_bytes_btfly']}"
                )
            total += level_sum
        table_rows = [
            r for r in doc["table"]
            if r["policy"] == policy and r.get("plan") == "btfly"
            and r.get("batch", 1) == 1
        ]
        assert table_rows, f"no btfly table row for policy {policy}"
        if table_rows[0]["bytes"] != total:
            raise SystemExit(
                f"{policy}: table btfly bytes {table_rows[0]['bytes']} != "
                f"staged sum {total}"
            )
    return n_checked


def check_batch(doc: dict) -> int:
    """Multi-source section: staged byte model + the per-source invariant."""
    batch = doc.get("batch")
    assert batch, "BENCH_comm.json lacks the multi-source batch section"
    s, n = doc["chunk"], doc["n"]
    n_checked = 0
    for policy, entry in batch["policies"].items():
        for e in entry.get("btfly_stages", ()):
            _check_stage(e, s, n, ctx=f"batch {policy}")
            n_checked += 1
        for plan, d in entry["plans"].items():
            per_src, b1 = d["bytes_per_source"], d["b1_total_bytes"]
            if policy in STRICT_BATCH_POLICIES and not per_src < b1:
                raise SystemExit(
                    f"batch {policy}/{plan}: bytes_per_source {per_src} not "
                    f"strictly below the B=1 total {b1} — the shared-header/"
                    "consensus amortization regressed"
                )
            if per_src > b1:
                raise SystemExit(
                    f"batch {policy}/{plan}: bytes_per_source {per_src} "
                    f"exceeds the B=1 total {b1}"
                )
            print(f"batch B={d['batch']} {policy}/{plan}: "
                  f"{per_src:.0f} B/source vs {b1} B at B=1")
            n_checked += 1
    return n_checked


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_comm.json"
    with open(path) as f:
        doc = json.load(f)
    assert "btfly" in doc.get("plans", ()), "BENCH_comm.json lacks the btfly plan"
    n = check(doc)
    nb = check_batch(doc)
    print(f"BENCH BTFLY BYTE MODEL OK ({n} stage entries checked)")
    print(f"BENCH BATCH MODEL OK ({nb} batch entries checked, "
          f"B={doc['batch']['B']})")


if __name__ == "__main__":
    main()
