"""Verify BENCH_comm.json's staged butterfly volumes against the static
byte model.

Usage: PYTHONPATH=src python scripts/check_bench_comm.py [BENCH_comm.json]

Every ``btfly_stages`` entry the host replay logged must satisfy

    bytes == senders * subchunks * stage_unit_bytes(s, n, fmt)

up to one packing chunk of padding per subchunk — the stage formats are
static-geometry wire formats, so any larger disagreement means the replay
and the device wire plan have diverged (the exact contamination the
butterfly-vs-alltoall comparison must not carry).  Also re-checks that the
per-level ``row_bytes_btfly`` totals equal the sum of their stages and that
the table's btfly row equals the per-level sum.
"""

from __future__ import annotations

import json
import sys

from repro.comm import butterfly

#: slack per subchunk: one 1024-value packing chunk of u32 words
PAD_BYTES = 4 * 1024


def check(doc: dict) -> int:
    s, n = doc["chunk"], doc["n"]
    n_checked = 0
    for policy, levels in doc["policy_levels"].items():
        total = 0
        for d in levels:
            level_sum = 0
            for e in d["btfly_stages"]:
                unit = butterfly.stage_unit_bytes(
                    s, n, e["fmt"], zone=e.get("zone", "row")
                )
                model = e["senders"] * e["subchunks"] * unit
                tol = e["senders"] * e["subchunks"] * PAD_BYTES
                if abs(e["bytes"] - model) > tol:
                    raise SystemExit(
                        f"{policy} level {d['level']} stage {e['stage']}: "
                        f"replayed {e['bytes']} B vs model {model} B "
                        f"(fmt={e['fmt']}, tol={tol})"
                    )
                level_sum += e["bytes"]
                n_checked += 1
            if level_sum != d["row_bytes_btfly"]:
                raise SystemExit(
                    f"{policy} level {d['level']}: stage sum {level_sum} != "
                    f"row_bytes_btfly {d['row_bytes_btfly']}"
                )
            total += level_sum
        table_rows = [
            r for r in doc["table"]
            if r["policy"] == policy and r.get("plan") == "btfly"
        ]
        assert table_rows, f"no btfly table row for policy {policy}"
        if table_rows[0]["bytes"] != total:
            raise SystemExit(
                f"{policy}: table btfly bytes {table_rows[0]['bytes']} != "
                f"staged sum {total}"
            )
    return n_checked


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_comm.json"
    with open(path) as f:
        doc = json.load(f)
    assert "btfly" in doc.get("plans", ()), "BENCH_comm.json lacks the btfly plan"
    n = check(doc)
    print(f"BENCH BTFLY BYTE MODEL OK ({n} stage entries checked)")


if __name__ == "__main__":
    main()
