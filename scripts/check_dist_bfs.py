"""Multi-device distributed-BFS correctness check (run with forced host devices).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=4 python scripts/check_dist_bfs.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import csr as csrmod
from repro.core import distributed_bfs as dbfs
from repro.core import validate
from repro.graphgen import builder, kronecker


def main() -> None:
    scale = 10
    g = builder.build_csr(kronecker.kronecker_edges(scale, seed=3), n=1 << scale)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    bg = csrmod.partition_2d(g, rows=2, cols=2)
    print(f"n={g.n} padded={bg.part.n} m_sym={g.m} e_cap={bg.e_cap} s={bg.part.chunk}")

    ref_levels = validate.reference_bfs(g, root=0)
    # wire modes (top_down) plus every traversal policy on the adaptive
    # plan; the low alpha forces direction_opt through its pull branch
    combos = [(m, "top_down", None) for m in ("raw", "bitmap", "auto", "btfly")]
    combos += [(m, p, 0.01) for m in ("auto", "btfly")
               for p in ("bottom_up", "direction_opt")]
    for mode, policy, alpha in combos:
        cfg = dbfs.DistBFSConfig(mode=mode, policy=policy, alpha=alpha)
        fn = dbfs.build_bfs(mesh, bg, cfg)
        src_l, dst_l = dbfs.shard_blocked(mesh, bg, cfg)
        parent, level, depth = fn(src_l, dst_l, jnp.int32(0))
        parent = np.asarray(parent)[: g.n]
        level = np.asarray(level)[: g.n]
        assert np.array_equal(level, ref_levels), (
            mode,
            policy,
            np.nonzero(level != ref_levels)[0][:10],
        )
        res = validate.validate_bfs_tree(g, parent, root=0, level=level)
        assert res.ok, (mode, policy, res.failures)
        print(f"mode={mode:7s} policy={policy:13s} OK "
              f"depth={int(depth)} reached={res.n_reached}")
    print("DIST BFS ALL MODES OK")


if __name__ == "__main__":
    main()
