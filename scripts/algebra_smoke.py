"""Frontier-algebra smoke gate: SSSP + CC on a scale-15 Kronecker graph,
2x2 grid, 4 forced host devices — fails on byte-model drift.

Usage: PYTHONPATH=src python scripts/algebra_smoke.py [--scale 15]

Three gates per (algebra x wire plan):

  1. CommStats <-> HLO reconciliation: the trace-time ledger must match
     the lowered program's collective bytes 1:1 per op kind (the tentpole
     acceptance — a recorded-but-dead or unrecorded collective fails here);
  2. static value-payload pricing: every ``values`` / ``dense-i32`` ledger
     record must equal ``check_bench_comm.value_unit_bytes`` exactly
     (density-independent formats leave no tolerance);
  3. reference correctness: the executed distances equal host Dijkstra
     over the same hashed weights, the labels equal union-find min-ids.

Exit status 1 on any drift, so CI can gate on it.
"""

from __future__ import annotations

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.comm import CommStats  # noqa: E402
from repro.core import csr as csrmod  # noqa: E402
from repro.core import distributed_bfs as dbfs  # noqa: E402
from repro.core import validate  # noqa: E402
from repro.graphgen import builder, kronecker  # noqa: E402
from repro.launch import roofline  # noqa: E402

import check_bench_comm as cbc  # noqa: E402  (sibling script)

ROWS = COLS = 2
MODES = ("auto", "btfly")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=15)
    args = ap.parse_args()

    t0 = time.perf_counter()
    g = builder.build_csr(
        kronecker.kronecker_edges(args.scale, seed=5), n=1 << args.scale
    )
    mesh = jax.make_mesh((ROWS, COLS), ("data", "model"))
    bg = csrmod.partition_2d(g, rows=ROWS, cols=COLS)
    part = bg.part
    root = int(np.argmax(g.degrees()))
    print(f"# scale={args.scale} n={g.n:,} m={g.m:,} s={part.chunk:,} "
          f"root={root} ({time.perf_counter() - t0:.1f}s setup)")

    print("# host oracles: Dijkstra + union-find ...", flush=True)
    host_sssp = validate.reference_sssp(g, root)
    host_cc = validate.reference_cc(g)

    roots = jnp.asarray(np.array([root], np.int32))
    for alg in ("sssp", "cc"):
        for mode in MODES:
            stats = CommStats()
            cfg = dbfs.DistBFSConfig(
                mode=mode, policy="direction_opt", algebra=alg, max_levels=256
            )
            fn = dbfs.build_bfs(mesh, part, cfg, stats=stats)
            blocks = dbfs.shard_blocked(mesh, bg, cfg)
            t0 = time.perf_counter()
            compiled = jax.jit(fn).lower(
                *blocks, jax.ShapeDtypeStruct((1,), jnp.int32)
            ).compile()
            cmp = roofline.compare_comm_stats(stats, compiled.as_text())
            if not cmp.match:
                raise SystemExit(
                    f"{alg}/{mode}: CommStats/HLO drift {cmp.diff()}"
                )
            n_val = cbc.check_value_records(
                stats.records(), s=part.chunk, r=ROWS, c=COLS
            )
            val, lev, dep = fn(*blocks, roots)
            got = np.asarray(val)[0][: g.n].astype(np.int64)
            host = host_sssp if alg == "sssp" else host_cc
            bad = int((got != host).sum())
            if bad:
                raise SystemExit(
                    f"{alg}/{mode}: {bad} vertices disagree with the host "
                    f"oracle (first: v={int(np.nonzero(got != host)[0][0])})"
                )
            print(f"{alg:5s}/{mode:5s}: HLO parity OK, {n_val} value-payload "
                  f"records priced, oracle exact, depth={int(dep)} "
                  f"({time.perf_counter() - t0:.1f}s)")
    print("ALGEBRA SMOKE OK")


if __name__ == "__main__":
    main()
