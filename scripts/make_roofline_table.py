"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun JSONs."""

import glob
import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_base2"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def fmt_b(x):
    if x is None:
        return "-"
    if x >= 1 << 30:
        return f"{x / (1 << 30):.2f}GiB"
    if x >= 1 << 20:
        return f"{x / (1 << 20):.1f}MiB"
    return f"{x / 1024:.0f}KiB"


rows = []
for fn in sorted(glob.glob(os.path.join(DIR, "*.json"))):
    with open(fn) as f:
        rows.append(json.load(f))

print("## §Dry-run (lower + compile on the production meshes)\n")
print("| arch | shape | mesh | status | compile | per-dev args | per-dev temps | HLO flops (raw) |")
print("|---|---|---|---|---|---|---|---|")
for r in rows:
    mem = r.get("memory", {})
    cost = r.get("cost", {})
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
          f"| {r.get('compile_s', '-')}s | {fmt_b(mem.get('argument_bytes'))} "
          f"| {fmt_b(mem.get('temp_bytes'))} | {cost.get('flops', 0):.3g} |")

print("\n## §Roofline (single-pod 16x16 = 256 chips)\n")
print("| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful-ratio | roofline-frac |")
print("|---|---|---|---|---|---|---|---|---|")
for r in rows:
    if r["mesh"] != "16x16":
        continue
    if r["status"] == "skip":
        print(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | ({r['skip_reason'][:60]}...) |")
        continue
    if r["status"] != "ok":
        continue
    t = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
          f"| {fmt_s(t['collective_s'])} | **{t['dominant']}** | {t['model_flops']:.3g} "
          f"| {t['useful_flop_ratio']:.3f} | {t['roofline_fraction']:.4f} |")

# candidates for hillclimbing
print("\n## hillclimb candidate ranking")
cands = [r for r in rows if r["mesh"] == "16x16" and r["status"] == "ok"]
by_frac = sorted(cands, key=lambda r: r["roofline"]["roofline_fraction"])[:6]
print("worst roofline fraction:")
for r in by_frac:
    print(f"  {r['arch']}/{r['shape']}: frac={r['roofline']['roofline_fraction']:.5f} dom={r['roofline']['dominant']}")
by_coll = sorted(cands, key=lambda r: -r["roofline"]["collective_s"])[:6]
print("most collective-bound:")
for r in by_coll:
    t = r["roofline"]
    print(f"  {r['arch']}/{r['shape']}: coll={fmt_s(t['collective_s'])} "
          f"({t['collective_s'] / max(t['compute_s'] + t['memory_s'] + t['collective_s'], 1e-12) * 100:.0f}% of sum) dom={t['dominant']}")
